//! Closed-loop multi-threaded load generator (DESIGN.md §11).
//!
//! Drives a running `pallas-serve` instance over loopback HTTP with job
//! submissions drawn from the Table-1 workload catalog, and reports
//! sustained request throughput and latency percentiles. Two modes:
//!
//! * [`LoadGen::paced`] — open-loop *target*, closed-loop *execution*:
//!   each client thread samples Poisson arrival times at its share of
//!   the target RPS and fires the next submit at its scheduled instant,
//!   but never queues more than one outstanding request (a thread that
//!   falls behind fires immediately instead of building an unbounded
//!   backlog, so the measured RPS is what the server actually absorbed);
//! * [`LoadGen::saturation`] — a fixed batch of jobs pushed back-to-back
//!   from every thread, measuring peak submit throughput. This is the
//!   mode behind the `service submit` benchmark cases and the CI
//!   `ratio_gates` entry asserting 4 shards ≥ 2× 1 shard.
//!
//! HTTP 200 counts as admitted, 409 as rejected-by-admission-control
//! (still a *successful* request), anything else as an error. The CI
//! service smoke asserts zero errors at low offered load.

use crate::service::api::{self as service_api, ServiceState};
use crate::service::http::{HttpClient, HttpServer};
use crate::service::shard::{ShardPool, ShardPoolConfig};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::workload::catalog;
use anyhow::{bail, Result};
use std::net::SocketAddr;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Monotonic per-process run counter: combined with the process id it
/// makes every generator run's job names unique, so a second `loadtest`
/// against the same long-running server is not a wall of
/// duplicate-name rejections.
static NEXT_RUN: AtomicUsize = AtomicUsize::new(0);

/// Shape of the jobs the generator submits.
#[derive(Debug, Clone)]
pub struct JobTemplate {
    pub length_hours: f64,
    pub slack: f64,
    pub max_servers: usize,
    /// Distinct tenant ids to spread submissions across shards.
    pub tenants: usize,
    pub seed: u64,
}

impl Default for JobTemplate {
    fn default() -> Self {
        JobTemplate {
            length_hours: 6.0,
            slack: 1.5,
            max_servers: 4,
            tenants: 64,
            seed: 1,
        }
    }
}

/// Aggregated load-test results.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub sent: usize,
    pub admitted: usize,
    pub rejected: usize,
    /// Transport failures and non-200/409 statuses.
    pub errors: usize,
    pub wall: Duration,
    /// Successfully answered requests (admitted + rejected) per second.
    pub sustained_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl LoadReport {
    pub fn completed(&self) -> usize {
        self.admitted + self.rejected
    }
}

#[derive(Debug, Default)]
struct ThreadStats {
    sent: usize,
    admitted: usize,
    rejected: usize,
    errors: usize,
    latencies_ms: Vec<f64>,
}

impl ThreadStats {
    fn fire(&mut self, client: &mut HttpClient, body: &str) {
        self.sent += 1;
        let t0 = Instant::now();
        match client.request("POST", "/v1/jobs", body) {
            Ok((200, _)) => {
                self.admitted += 1;
                self.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            Ok((409, _)) => {
                self.rejected += 1;
                self.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            Ok(_) | Err(_) => self.errors += 1,
        }
    }
}

/// The generator: a target address, a client-thread count, and the job
/// shape to submit.
pub struct LoadGen {
    addr: SocketAddr,
    threads: usize,
    template: JobTemplate,
    /// Run-unique job-name prefix (process id + run counter).
    tag: String,
}

impl LoadGen {
    pub fn new(addr: SocketAddr, threads: usize, template: JobTemplate) -> Self {
        LoadGen {
            addr,
            threads: threads.max(1),
            template,
            tag: format!(
                "{:x}.{}",
                std::process::id(),
                NEXT_RUN.fetch_add(1, Ordering::Relaxed)
            ),
        }
    }

    /// Poisson-paced submissions at `target_rps` for `duration`.
    pub fn paced(&self, target_rps: f64, duration: Duration) -> Result<LoadReport> {
        if target_rps <= 0.0 {
            bail!("target RPS must be positive");
        }
        let rate_per_thread = target_rps / self.threads as f64;
        let t0 = Instant::now();
        let per_thread = self.run_threads(|gen, t| gen.paced_worker(t, rate_per_thread, duration));
        Ok(merge(per_thread, t0.elapsed()))
    }

    /// Back-to-back submission of exactly `n_jobs` jobs.
    pub fn saturation(&self, n_jobs: usize) -> Result<LoadReport> {
        if n_jobs == 0 {
            bail!("need at least one job");
        }
        let t0 = Instant::now();
        let per_thread = self.run_threads(|gen, t| gen.saturation_worker(t, n_jobs));
        Ok(merge(per_thread, t0.elapsed()))
    }

    fn run_threads<F>(&self, work: F) -> Vec<ThreadStats>
    where
        F: Fn(&LoadGen, usize) -> ThreadStats + Sync,
    {
        let work = &work;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..self.threads)
                .map(|t| scope.spawn(move || work(self, t)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("loadgen thread panicked"))
                .collect()
        })
    }

    fn paced_worker(&self, t: usize, rate_per_thread: f64, duration: Duration) -> ThreadStats {
        let mut rng = Rng::new(
            self.template
                .seed
                .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(t as u64 + 1)),
        );
        let mut client = HttpClient::new(self.addr);
        let mut stats = ThreadStats::default();
        let names = catalog::names();
        let start = Instant::now();
        let deadline = start + duration;
        let mut next = start;
        let mut k = 0usize;
        loop {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            if next > now {
                std::thread::sleep((next - now).min(deadline - now));
                if Instant::now() >= deadline {
                    break;
                }
            }
            let tenant = rng.below(self.template.tenants.max(1) as u64) as usize;
            let name = format!("lg{}-{t}-{k}", self.tag);
            let body = self.job_body(&name, tenant, names[k % names.len()]);
            stats.fire(&mut client, &body);
            k += 1;
            // Next Poisson arrival; behind-schedule threads fire
            // immediately (closed loop, no backlog).
            let gap = -(1.0 - rng.f64()).ln() / rate_per_thread;
            next += Duration::from_secs_f64(gap);
            let now = Instant::now();
            if next < now {
                next = now;
            }
        }
        stats
    }

    fn saturation_worker(&self, t: usize, n_jobs: usize) -> ThreadStats {
        let mut client = HttpClient::new(self.addr);
        let mut stats = ThreadStats::default();
        let names = catalog::names();
        let mut idx = t;
        while idx < n_jobs {
            let name = format!("lg{}-{idx}", self.tag);
            let body = self.job_body(
                &name,
                idx % self.template.tenants.max(1),
                names[idx % names.len()],
            );
            stats.fire(&mut client, &body);
            idx += self.threads;
        }
        stats
    }

    fn job_body(&self, name: &str, tenant: usize, workload: &str) -> String {
        Json::obj()
            .set("name", name)
            .set("tenant", format!("tenant-{tenant}"))
            .set("workload", workload)
            .set("maxServers", self.template.max_servers)
            .set("lengthHours", self.template.length_hours)
            .set("slackFactor", self.template.slack)
            .to_string_compact()
    }
}

/// Where the simulated crash lands relative to the group-commit
/// pipeline (DESIGN.md §14).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KillMode {
    /// [`ShardPool::kill`]: the writer drains staged records, so the
    /// log ends at the last processed batch boundary.
    Boundary,
    /// [`ShardPool::kill_mid_commit`]: records buffered but not yet
    /// fsynced are destroyed, as if the process died between `write`
    /// and `fsync`. Their acks are never released, so the durability
    /// contract (`200 ⇒ crash-durable`) must still hold — the scenario
    /// asserts unacked-only loss.
    MidCommit,
}

/// Result of the kill-and-recover durability scenario (DESIGN.md §14).
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    /// Jobs acknowledged with HTTP 200 before the kill landed.
    pub acked: usize,
    /// Acknowledged jobs missing after recovery. The durability contract
    /// is that this is empty: a 200 reply implies the admission was
    /// fsync'd to the WAL first.
    pub lost: Vec<String>,
    /// Engine events replayed from the WAL tails across all shards.
    pub replayed_events: usize,
    /// Bytes left in the WALs at the kill point (post-compaction tails).
    pub wal_bytes: u64,
    /// Wall time of restarting the pool over the crashed data dir
    /// (snapshot load + WAL replay for every shard).
    pub recovery: Duration,
}

/// The kill-and-recover scenario behind `serve --selftest-recover` and
/// the CI `durability` job: run a durable in-process service under
/// multi-threaded submit/complete/revise load, tear it down
/// SIGKILL-equivalently mid-stream once `kill_after` submissions have
/// been acknowledged — at a batch boundary or mid-group-commit,
/// per [`KillMode`] — restart a pool over the same data dir, and report
/// every acknowledged job the recovered state fails to account for.
pub fn kill_and_recover(
    shards: usize,
    cluster: usize,
    carbon: Vec<f64>,
    dir: &Path,
    threads: usize,
    kill_after: usize,
    mode: KillMode,
) -> Result<RecoveryReport> {
    let cfg = || {
        ShardPoolConfig::new(shards, cluster, carbon.clone())
            .durable(dir)
            // Small cadence so the scenario exercises snapshot
            // compaction *and* WAL-tail replay, not just one of them.
            .compact_every(8)
    };
    let pool = ShardPool::start(cfg())?;
    let state = ServiceState::new(pool);
    let server = HttpServer::bind(
        "127.0.0.1:0",
        threads.max(2),
        service_api::handler(state.clone()),
    )?;
    let addr = server.addr();

    let acked = Mutex::new(Vec::<String>::new());
    let acked_n = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let carbon_ref = &carbon;
    std::thread::scope(|scope| {
        for t in 0..threads.max(1) {
            let acked = &acked;
            let acked_n = &acked_n;
            let stop = &stop;
            scope.spawn(move || {
                let mut client = HttpClient::new(addr);
                let names = catalog::names();
                let mut k = 0usize;
                while !stop.load(Ordering::SeqCst) {
                    let name = format!("kr-{t}-{k}");
                    let body = Json::obj()
                        .set("name", name.as_str())
                        .set("tenant", format!("tenant-{}", (t * 31 + k) % 16))
                        .set("workload", names[k % names.len()])
                        .set("maxServers", 4usize)
                        .set("lengthHours", 1.0)
                        .set("slackFactor", 3.0)
                        .to_string_compact();
                    match client.request("POST", "/v1/jobs", &body) {
                        Ok((200, _)) => {
                            acked.lock().expect("acked poisoned").push(name.clone());
                            acked_n.fetch_add(1, Ordering::SeqCst);
                            // Sprinkle completions and forecast revisions
                            // so every WAL record kind lands in the
                            // replayed tail, not just arrivals.
                            if k % 3 == 1 {
                                let _ = client
                                    .request("POST", &format!("/v1/jobs/{name}/complete"), "");
                            }
                            if t == 0 && k % 5 == 2 {
                                let n = carbon_ref.len().min(8);
                                let bump = (k % 3) as f64 * 10.0;
                                let vals: Vec<Json> = carbon_ref[..n]
                                    .iter()
                                    .map(|c| Json::Num(c + bump))
                                    .collect();
                                let body = Json::obj()
                                    .set("start", 0usize)
                                    .set("carbon", Json::Arr(vals))
                                    .to_string_compact();
                                let _ = client.request("POST", "/v1/forecast", &body);
                            }
                        }
                        Ok(_) => {}       // rejected or post-kill 5xx
                        Err(_) => break,  // connection died: kill landed
                    }
                    k += 1;
                }
            });
        }
        // The killer: wait for enough acknowledgements, then pull the
        // plug mid-stream. The time bound is a failsafe against a
        // misconfigured scenario (cluster too small to ever ack
        // `kill_after` jobs) hanging the CI job.
        let t_kill = Instant::now();
        while acked_n.load(Ordering::SeqCst) < kill_after
            && t_kill.elapsed() < Duration::from_secs(30)
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        stop.store(true, Ordering::SeqCst);
        match mode {
            KillMode::Boundary => state.pool().kill(),
            KillMode::MidCommit => state.pool().kill_mid_commit(),
        }
        server.shutdown();
    });
    let acked = acked.into_inner().expect("acked poisoned");
    let wal_bytes: u64 = state.pool().snapshots().iter().map(|s| s.wal_bytes).sum();

    let t0 = Instant::now();
    let recovered = ShardPool::start(cfg())?;
    let recovery = t0.elapsed();
    let snaps = recovered.snapshots();
    let replayed_events: usize = snaps.iter().map(|s| s.replayed_events).sum();
    let known: std::collections::HashSet<&str> = snaps
        .iter()
        .flat_map(|s| s.jobs.iter().map(|j| j.name.as_str()))
        .collect();
    let lost: Vec<String> = acked
        .iter()
        .filter(|n| !known.contains(n.as_str()))
        .cloned()
        .collect();
    recovered.shutdown();
    Ok(RecoveryReport {
        acked: acked.len(),
        lost,
        replayed_events,
        wal_bytes,
        recovery,
    })
}

fn merge(per_thread: Vec<ThreadStats>, wall: Duration) -> LoadReport {
    let mut sent = 0;
    let mut admitted = 0;
    let mut rejected = 0;
    let mut errors = 0;
    let mut latencies: Vec<f64> = Vec::new();
    for t in per_thread {
        sent += t.sent;
        admitted += t.admitted;
        rejected += t.rejected;
        errors += t.errors;
        latencies.extend(t.latencies_ms);
    }
    latencies.sort_by(f64::total_cmp);
    let (mean_ms, p50_ms, p99_ms) = if latencies.is_empty() {
        (0.0, 0.0, 0.0)
    } else {
        (
            stats::mean(&latencies),
            stats::percentile_sorted(&latencies, 50.0),
            stats::percentile_sorted(&latencies, 99.0),
        )
    };
    LoadReport {
        sent,
        admitted,
        rejected,
        errors,
        wall,
        sustained_rps: (admitted + rejected) as f64 / wall.as_secs_f64().max(1e-9),
        mean_ms,
        p50_ms,
        p99_ms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::api::{self, ServiceState};
    use crate::service::http::HttpServer;
    use crate::service::shard::{ShardPool, ShardPoolConfig};

    fn service(shards: usize, cluster: usize) -> (HttpServer, std::sync::Arc<ServiceState>) {
        let carbon: Vec<f64> = (0..24).map(|h| 50.0 + 30.0 * ((h % 8) as f64)).collect();
        let pool = ShardPool::start(ShardPoolConfig::new(shards, cluster, carbon)).unwrap();
        let state = ServiceState::new(pool);
        let server =
            HttpServer::bind("127.0.0.1:0", 4, api::handler(std::sync::Arc::clone(&state)))
                .unwrap();
        (server, state)
    }

    #[test]
    fn saturation_submits_exactly_n_jobs_without_errors() {
        let (server, state) = service(2, 16);
        let gen = LoadGen::new(server.addr(), 3, JobTemplate::default());
        let report = gen.saturation(12).unwrap();
        assert_eq!(report.sent, 12);
        assert_eq!(report.errors, 0);
        assert_eq!(report.completed(), 12);
        assert!(report.sustained_rps > 0.0);
        assert!(report.p50_ms <= report.p99_ms);
        let totals = state.pool().totals();
        assert_eq!(totals.submitted, 12);
        assert_eq!(totals.admitted + totals.rejected, 12);
        server.shutdown();
        state.pool().shutdown();
    }

    #[test]
    fn paced_run_reports_sane_latency_stats() {
        let (server, state) = service(1, 8);
        let gen = LoadGen::new(server.addr(), 2, JobTemplate::default());
        let report = gen
            .paced(40.0, Duration::from_millis(300))
            .unwrap();
        assert!(report.sent > 0, "paced run must submit something");
        assert_eq!(report.errors, 0);
        assert!(report.mean_ms >= 0.0);
        server.shutdown();
        state.pool().shutdown();
    }
}
