//! CarbonScaler CLI.
//!
//! Subcommands:
//!   expt <id|all>      regenerate a paper table/figure (see DESIGN.md §5)
//!   advisor            simulate a job spec under all policies
//!   trace              generate / inspect synthetic carbon traces
//!   regions            list the region catalog
//!   profile            profile the real elastic training pool
//!   train              run the end-to-end PJRT training under CarbonScaler
//!   submit             plan a job spec and print its schedule
//!   serve              run pallas-serve, the sharded scheduler-as-a-service
//!   loadtest           drive a running service instance at a target RPS

use anyhow::{anyhow, bail, Result};
use carbonscaler::advisor::{self, SimConfig};
use carbonscaler::carbon::{regions, synthetic};
use carbonscaler::cluster::api;
use carbonscaler::coordinator::{CarbonAutoscaler, RunConfig};
use carbonscaler::expt::{self, ExpContext};
use carbonscaler::profiler;
use carbonscaler::runtime::{Manifest, WorkerPool};
use carbonscaler::sched::{
    CarbonAgnostic, CarbonScalerPolicy, OracleStaticScale, Policy, StaticScale,
    SuspendResumeDeadline,
};
use carbonscaler::service::api::{self as service_api, ServiceState};
use carbonscaler::service::http::{HttpClient, HttpServer};
use carbonscaler::service::loadgen::{self, JobTemplate, KillMode, LoadGen, LoadReport};
use carbonscaler::service::shard::{ShardPool, ShardPoolConfig};
use carbonscaler::service::wal::GroupCommitOpts;
use carbonscaler::util::cli::{Args, ArgSpec};
use carbonscaler::util::json::{self, Json};
use carbonscaler::util::table::{f, pct, Table};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

const USAGE: &str =
    "carbonscaler <expt|advisor|trace|regions|profile|train|submit|serve|loadtest> [options]
Reproduction of CarbonScaler (SIGMETRICS/POMACS 2023). See README.md.";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "expt" => cmd_expt(rest),
        "advisor" => cmd_advisor(rest),
        "trace" => cmd_trace(rest),
        "regions" => cmd_regions(),
        "profile" => cmd_profile(rest),
        "train" => cmd_train(rest),
        "submit" => cmd_submit(rest),
        "serve" => cmd_serve(rest),
        "loadtest" => cmd_loadtest(rest),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn parse(rest: &[String], specs: &[ArgSpec], head: &str) -> Result<Args> {
    Args::parse(rest, specs, head).map_err(|e| anyhow!("{e}"))
}

fn cmd_expt(rest: &[String]) -> Result<()> {
    const SPECS: &[ArgSpec] = &[
        ArgSpec::opt("seed", "trace/error seed", "2023"),
        ArgSpec::flag("quick", "reduced sweep sizes"),
    ];
    let args = parse(rest, SPECS, "carbonscaler expt <id|all> [--quick]")?;
    let ctx = ExpContext {
        seed: args.u64("seed")?,
        quick: args.flag("quick"),
    };
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    if id == "all" {
        for e in expt::all() {
            expt::run_and_print(e.id(), &ctx)?;
        }
    } else if id == "list" {
        for e in expt::all() {
            println!("{:8} {}", e.id(), e.title());
        }
    } else {
        expt::run_and_print(id, &ctx)?;
    }
    Ok(())
}

fn cmd_advisor(rest: &[String]) -> Result<()> {
    const SPECS: &[ArgSpec] = &[
        ArgSpec::req("job", "path to a job spec JSON (see examples/jobspec.json)"),
        ArgSpec::opt("seed", "trace seed", "2023"),
        ArgSpec::opt("weeks", "trace length in weeks", "6"),
        ArgSpec::opt("forecast-error", "forecast error fraction", "0.0"),
        ArgSpec::opt("denial-prob", "procurement denial probability", "0.0"),
    ];
    let args = parse(rest, SPECS, "carbonscaler advisor --job <spec.json>")?;
    let req = api::load_job_request(&PathBuf::from(args.str("job")?))?;
    let trace = synthetic::generate(
        regions::by_name(&req.region).unwrap(),
        args.usize("weeks")? * 7 * 24,
        args.u64("seed")?,
    );
    let cfg = SimConfig {
        forecast_error: args.f64("forecast-error")?,
        denial_prob: args.f64("denial-prob")?,
        ..Default::default()
    };

    let policies: Vec<Box<dyn Policy>> = vec![
        Box::new(CarbonAgnostic),
        Box::new(SuspendResumeDeadline),
        Box::new(StaticScale::new(2.min(req.spec.max_servers))),
        Box::new(OracleStaticScale),
        Box::new(CarbonScalerPolicy),
    ];
    let mut t = Table::new(&format!(
        "advisor: {} in {} (l={}h, T={}h, m={}, M={})",
        req.spec.name,
        req.region,
        req.spec.length_hours,
        req.spec.completion_hours,
        req.spec.min_servers,
        req.spec.max_servers
    ))
    .headers(&["policy", "carbon (g)", "completion (h)", "server-hours", "switches"]);
    let mut base = None;
    for p in &policies {
        match advisor::simulate(p.as_ref(), &req.spec, &trace, &cfg) {
            Ok(r) => {
                if p.name() == "carbon-agnostic" {
                    base = Some(r.carbon_g);
                }
                t.row(vec![
                    p.name(),
                    f(r.carbon_g, 1),
                    r.completion_hours.map(|c| f(c, 1)).unwrap_or("-".into()),
                    f(r.server_hours, 1),
                    r.n_switches.to_string(),
                ]);
            }
            Err(e) => {
                t.row(vec![
                    p.name(),
                    format!("infeasible: {e}"),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
    }
    t.print();
    if let Some(b) = base {
        let cs = advisor::simulate(&CarbonScalerPolicy, &req.spec, &trace, &cfg)?;
        println!(
            "\ncarbonscaler saves {} vs carbon-agnostic",
            pct(advisor::savings_pct(b, cs.carbon_g))
        );
    }
    Ok(())
}

fn cmd_trace(rest: &[String]) -> Result<()> {
    const SPECS: &[ArgSpec] = &[
        ArgSpec::opt("region", "region name", "ontario"),
        ArgSpec::opt("hours", "trace length", "168"),
        ArgSpec::opt("seed", "generator seed", "2023"),
        ArgSpec::opt("out", "CSV output path (- for summary only)", "-"),
    ];
    let args = parse(rest, SPECS, "carbonscaler trace [--region r] [--out f.csv]")?;
    let region = args.str("region")?;
    let r = regions::by_name(&region).ok_or_else(|| anyhow!("unknown region {region:?}"))?;
    let trace = synthetic::generate(r, args.usize("hours")?, args.u64("seed")?);
    println!(
        "{}: {} hours, mean {:.0} gCO2/kWh, daily CoV {:.3}, p25 {:.0}, p75 {:.0}",
        trace.region,
        trace.len(),
        trace.mean(),
        trace.daily_coeff_of_variation(),
        trace.percentile(25.0),
        trace.percentile(75.0)
    );
    let out = args.str("out")?;
    if out != "-" {
        trace.save_csv(&PathBuf::from(&out))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_regions() -> Result<()> {
    let mut t = Table::new("region catalog (synthetic parameters, DESIGN.md §3)")
        .headers(&["region", "mean g/kWh", "CoV", "solar share"]);
    for r in regions::REGIONS {
        t.row(vec![
            r.name.to_string(),
            f(r.mean, 0),
            f(r.cov, 2),
            f(r.solar, 2),
        ]);
    }
    t.print();
    Ok(())
}

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn cmd_profile(rest: &[String]) -> Result<()> {
    const SPECS: &[ArgSpec] = &[
        ArgSpec::opt("preset", "artifact preset (tiny|small)", "tiny"),
        ArgSpec::opt("workers", "max workers to profile", "4"),
        ArgSpec::opt("alpha-secs", "seconds per allocation level", "2"),
        ArgSpec::opt("beta", "allocation granularity", "1"),
    ];
    let args = parse(rest, SPECS, "carbonscaler profile [--preset tiny]")?;
    let m = Manifest::load(&artifacts_dir())?;
    let preset = args.str("preset")?;
    let art = m
        .transformer(&preset)
        .ok_or_else(|| anyhow!("no artifact for preset {preset:?} — run `make artifacts`"))?;
    let pool = WorkerPool::spawn(art, args.usize("workers")?, 42)?;
    let report = profiler::profile_pool(
        &pool,
        &profiler::ProfilerConfig {
            alpha: std::time::Duration::from_secs_f64(args.f64("alpha-secs")?),
            beta: args.usize("beta")?,
            ..Default::default()
        },
    )?;
    let mut t = Table::new("measured scaling profile (real PJRT pool)")
        .headers(&["workers", "samples/sec", "relative capacity"]);
    for (i, &k) in report.levels.iter().enumerate() {
        t.row(vec![
            k.to_string(),
            f(report.throughputs[i], 1),
            f(report.throughputs[i] / report.throughputs[0], 2),
        ]);
    }
    t.print();
    println!(
        "\nmarginal capacity curve: {:?}\nprofiling took {:.1}s",
        report
            .curve
            .marginals()
            .iter()
            .map(|v| (v * 100.0).round() / 100.0)
            .collect::<Vec<_>>(),
        report.elapsed.as_secs_f64()
    );
    pool.shutdown();
    Ok(())
}

fn cmd_train(rest: &[String]) -> Result<()> {
    const SPECS: &[ArgSpec] = &[
        ArgSpec::opt("preset", "artifact preset (tiny|small)", "small"),
        ArgSpec::opt("workers", "max workers (M)", "4"),
        ArgSpec::opt("length", "job length in trace hours", "8"),
        ArgSpec::opt("slack", "completion factor T/l", "1.5"),
        ArgSpec::opt("slot-secs", "wall seconds per trace hour", "3"),
        ArgSpec::opt("region", "carbon region", "ontario"),
        ArgSpec::opt("seed", "seed", "42"),
    ];
    let args = parse(rest, SPECS, "carbonscaler train [--preset small]")?;
    let m = Manifest::load(&artifacts_dir())?;
    let preset = args.str("preset")?;
    let art = m
        .transformer(&preset)
        .ok_or_else(|| anyhow!("no artifact for preset {preset:?}"))?;
    let workers = args.usize("workers")?;
    println!(
        "spawning {workers} PJRT workers (P={} params)...",
        art.n_params
    );
    let pool = WorkerPool::spawn(art, workers, args.u64("seed")?)?;

    // Measure the real scaling profile, then schedule with it.
    let report = profiler::profile_pool(
        &pool,
        &profiler::ProfilerConfig {
            alpha: std::time::Duration::from_millis(800),
            ..Default::default()
        },
    )?;
    println!(
        "measured capacity curve: {:?}",
        report
            .curve
            .marginals()
            .iter()
            .map(|v| (v * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );

    let region = args.str("region")?;
    let trace = synthetic::generate(
        regions::by_name(&region).ok_or_else(|| anyhow!("unknown region"))?,
        14 * 24,
        args.u64("seed")?,
    );
    let job = carbonscaler::workload::JobBuilder::new("train-e2e", report.curve.clone())
        .servers(1, workers)
        .length(args.f64("length")?)
        .slack_factor(args.f64("slack")?)
        .power(210.0)
        .build()?;
    let auto = CarbonAutoscaler::new(
        &pool,
        job.clone(),
        trace.clone(),
        RunConfig {
            slot_seconds: args.f64("slot-secs")?,
            seed: args.u64("seed")?,
            ..Default::default()
        },
    )?;
    println!("running CarbonScaler schedule ({} slots)...", job.n_slots());
    let r = auto.run(&CarbonScalerPolicy)?;

    let mut t = Table::new("per-slot execution").headers(&[
        "slot",
        "workers",
        "steps",
        "mean loss",
        "carbon (g)",
    ]);
    for s in &r.slots {
        t.row(vec![
            s.slot.to_string(),
            s.workers.to_string(),
            s.steps.to_string(),
            if s.mean_loss.is_nan() {
                "-".into()
            } else {
                f(s.mean_loss as f64, 3)
            },
            f(s.carbon_g, 2),
        ]);
    }
    t.print();
    println!(
        "\ntotal: {} steps, {} samples, {:.1} g CO2, {:.3} kWh, completion {:?}h, final loss {:.3} (wall {:.1}s)",
        r.total_steps,
        r.total_samples,
        r.carbon_g,
        r.energy_kwh,
        r.completion_hours,
        r.final_loss,
        r.wall_seconds
    );
    pool.shutdown();
    Ok(())
}

fn print_load_report(report: &LoadReport) {
    let mut t = Table::new("load test").headers(&[
        "sent",
        "admitted",
        "rejected",
        "errors",
        "sustained rps",
        "mean ms",
        "p50 ms",
        "p99 ms",
    ]);
    t.row(vec![
        report.sent.to_string(),
        report.admitted.to_string(),
        report.rejected.to_string(),
        report.errors.to_string(),
        f(report.sustained_rps, 1),
        f(report.mean_ms, 2),
        f(report.p50_ms, 2),
        f(report.p99_ms, 2),
    ]);
    t.print();
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    const SPECS: &[ArgSpec] = &[
        ArgSpec::opt("port", "TCP port on 127.0.0.1 (0 = ephemeral)", "8080"),
        ArgSpec::opt("shards", "engine shards (planning threads)", "4"),
        ArgSpec::opt("cluster-size", "total servers, split across shards", "64"),
        ArgSpec::opt("horizon", "planning window in hours", "168"),
        ArgSpec::opt("region", "carbon region for the forecast", "ontario"),
        ArgSpec::opt("seed", "forecast trace seed", "2023"),
        ArgSpec::opt("http-workers", "HTTP worker threads", "8"),
        ArgSpec::opt("secs", "run duration in seconds (0 = until killed)", "0"),
        ArgSpec::opt("data-dir", "per-shard WAL + snapshot dir", "pallas-data"),
        ArgSpec::flag("no-wal", "run in-memory only (no durability, no recovery)"),
        ArgSpec::opt("compact-every", "batches between WAL compactions", "256"),
        ArgSpec::opt(
            "group-commit-max-delay",
            "extra ms the WAL writer may wait to widen a group commit (0 = natural batching only)",
            "0",
        ),
        ArgSpec::opt(
            "group-commit-max-bytes",
            "flush a group commit early once this many buffered WAL bytes accumulate",
            "1048576",
        ),
        ArgSpec::flag(
            "group-commit-adaptive",
            "tune the group-commit delay online from observed ack lag (bounded AIAD)",
        ),
        ArgSpec::flag(
            "fsync-per-batch",
            "legacy durability ordering: the planning thread waits for fsync before replying",
        ),
        ArgSpec::flag("selftest", "drive an in-process load test, then exit"),
        ArgSpec::flag(
            "selftest-recover",
            "run the kill-and-recover durability scenario, then exit",
        ),
        ArgSpec::opt("rps", "selftest target RPS", "20"),
        ArgSpec::opt("threads", "selftest client threads", "4"),
    ];
    let args = parse(rest, SPECS, "carbonscaler serve [--shards 4] [--selftest]")?;
    let region_name = args.str("region")?;
    let region = regions::by_name(&region_name)
        .ok_or_else(|| anyhow!("unknown region {region_name:?}"))?;
    let horizon = args.usize("horizon")?;
    let trace = synthetic::generate(region, horizon, args.u64("seed")?);
    let shards = args.usize("shards")?;
    let cluster = args.usize("cluster-size")?;
    let no_wal = args.flag("no-wal");
    let selftest = args.flag("selftest");

    if args.flag("selftest-recover") {
        return cmd_serve_recover(&args, shards, cluster, trace.window(0, horizon), no_wal);
    }

    let mut cfg = ShardPoolConfig::new(shards, cluster, trace.window(0, horizon))
        .compact_every(args.usize("compact-every")?)
        .group_commit(GroupCommitOpts {
            max_delay: Duration::from_millis(args.u64("group-commit-max-delay")?),
            max_bytes: args.u64("group-commit-max-bytes")?,
            adaptive: args.flag("group-commit-adaptive"),
            ..GroupCommitOpts::default()
        });
    if args.flag("fsync-per-batch") {
        cfg = cfg.per_batch_fsync();
    }
    // The selftest must not inherit (or pollute) a real deployment's
    // data dir: it gets a throwaway directory, removed on exit.
    let selftest_dir = (selftest && !no_wal).then(|| ephemeral_data_dir("selftest"));
    if let Some(dir) = &selftest_dir {
        let _ = std::fs::remove_dir_all(dir);
        cfg = cfg.durable(dir);
    } else if !no_wal {
        cfg = cfg.durable(args.str("data-dir")?);
    }
    let durability = match (&selftest_dir, no_wal) {
        (_, true) => "in-memory (--no-wal)".to_string(),
        (Some(dir), _) => format!("durable, throwaway {}", dir.display()),
        (None, _) => format!("durable, {}", args.str("data-dir")?),
    };
    let pool = ShardPool::start(cfg)?;
    let state = ServiceState::new(pool);
    let server = HttpServer::bind(
        &format!("127.0.0.1:{}", args.usize("port")?),
        args.usize("http-workers")?,
        service_api::handler(state.clone()),
    )?;
    println!(
        "pallas-serve listening on http://{} ({shards} shards, {cluster} servers, \
         {horizon} h window, forecast {region_name}, {durability})",
        server.addr()
    );

    if selftest {
        let secs = args.f64("secs")?;
        let duration = Duration::from_secs_f64(if secs > 0.0 { secs } else { 10.0 });
        let rps = args.f64("rps")?;
        println!("selftest: {rps} RPS for {:.0} s ...", duration.as_secs_f64());
        // Revision storm: a sidecar thread posts alternating forecast
        // revisions while the load test runs, so admission batches and
        // coalesced revision batches interleave and the dirty-repair
        // path (DESIGN.md §13) is exercised under live traffic.
        let storm_stop = Arc::new(AtomicBool::new(false));
        let storm = {
            let stop = Arc::clone(&storm_stop);
            let addr = server.addr();
            let base = trace.window(0, horizon.min(8));
            std::thread::spawn(move || -> Result<(usize, usize)> {
                let mut client = HttpClient::new(addr);
                let mut applied = 0usize;
                let mut sent = 0usize;
                while !stop.load(Ordering::SeqCst) {
                    let bump = if sent % 2 == 1 { 25.0 } else { 0.0 };
                    let vals: Vec<String> =
                        base.iter().map(|c| format!("{:.3}", c + bump)).collect();
                    let body = format!(r#"{{"start": 0, "carbon": [{}]}}"#, vals.join(","));
                    let (status, _) = client.request("POST", "/v1/forecast", &body)?;
                    sent += 1;
                    if status == 200 {
                        applied += 1;
                    }
                    std::thread::sleep(Duration::from_millis(200));
                }
                Ok((applied, sent))
            })
        };
        // Interactive request streams (DESIGN.md §15): register a few
        // with known demand before the batch load starts, so the final
        // reconciliation can assert grant conservation — every demanded
        // server-slot comes back either reserved or violated.
        const SELFTEST_STREAMS: usize = 3;
        let mut svc_demand_units = 0usize;
        {
            let mut client = HttpClient::new(server.addr());
            for i in 0..SELFTEST_STREAMS {
                let body = format!(
                    r#"{{"name": "selftest-stream-{i}", "tenant": "stream-{i}", "start": 0, "demand": [1, 2, 1]}}"#
                );
                let (status, resp) = client.request("POST", "/v1/services", &body)?;
                if status != 200 {
                    bail!("selftest stream registration failed ({status}): {resp}");
                }
                svc_demand_units += 4;
            }
        }
        let gen = LoadGen::new(server.addr(), args.usize("threads")?, JobTemplate::default());
        let report = gen.paced(rps, duration)?;
        storm_stop.store(true, Ordering::SeqCst);
        let (storm_applied, storm_sent) = storm.join().expect("revision storm panicked")?;
        print_load_report(&report);
        let snaps = state.pool().snapshots();
        let batches: usize = snaps.iter().map(|s| s.batches).sum();
        let events: usize = snaps.iter().map(|s| s.batched_events).sum();
        let dirty: usize = snaps.iter().map(|s| s.dirty_slots).sum();
        println!(
            "shards processed {events} events in {batches} batches \
             ({:.2} events/batch)",
            events as f64 / batches.max(1) as f64
        );
        println!(
            "revision storm: {storm_applied}/{storm_sent} forecast revisions \
             applied, {dirty} dirty slots repaired"
        );
        // Snapshot the public counters before teardown so we can
        // reconcile them against what the clients actually saw.
        let stats_doc = HttpClient::new(server.addr())
            .request("GET", "/v1/stats", "")
            .ok()
            .and_then(|(status, body)| (status == 200).then_some(body))
            .and_then(|body| json::parse(&body).ok());
        server.shutdown();
        state.pool().shutdown();
        let verdict = (|| -> Result<()> {
            if report.errors > 0 {
                bail!("selftest saw {} transport errors", report.errors);
            }
            if report.completed() == 0 {
                bail!("selftest completed zero requests");
            }
            if storm_applied == 0 || storm_applied != storm_sent {
                bail!("revision storm applied {storm_applied}/{storm_sent} revisions");
            }
            let doc = stats_doc.ok_or_else(|| anyhow!("selftest could not fetch /v1/stats"))?;
            let field = |k: &str| {
                doc.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("/v1/stats is missing {k:?}"))
            };
            let (submitted, admitted, rejected) =
                (field("submitted")?, field("admitted")?, field("rejected")?);
            if submitted != admitted + rejected
                || admitted != report.admitted
                || rejected != report.rejected
            {
                bail!(
                    "counters do not reconcile: /v1/stats says {submitted} submitted = \
                     {admitted} admitted + {rejected} rejected, but clients saw \
                     {} admitted + {} rejected",
                    report.admitted,
                    report.rejected
                );
            }
            let services = field("services")?;
            let reserved = field("interactiveReserved")?;
            let violations = field("sloViolations")?;
            if services != SELFTEST_STREAMS || reserved + violations != svc_demand_units {
                bail!(
                    "interactive counters do not reconcile: /v1/stats says {services} \
                     streams with {reserved} reserved + {violations} violations, but \
                     {SELFTEST_STREAMS} streams demanded {svc_demand_units} server-slots"
                );
            }
            Ok(())
        })();
        if let Some(dir) = &selftest_dir {
            let _ = std::fs::remove_dir_all(dir);
        }
        verdict?;
        println!(
            "selftest OK: zero errors, counters reconcile, sustained {:.1} RPS",
            report.sustained_rps
        );
        return Ok(());
    }

    let secs = args.f64("secs")?;
    if secs > 0.0 {
        std::thread::sleep(Duration::from_secs_f64(secs));
        server.shutdown();
        state.pool().shutdown();
    } else {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    Ok(())
}

/// Throwaway per-process data dir for the self-test modes, so they never
/// inherit or pollute a real deployment's `--data-dir`.
fn ephemeral_data_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pallas-serve-{tag}-{}", std::process::id()))
}

/// `serve --selftest-recover`: the kill-and-recover durability scenario
/// (DESIGN.md §14) against a throwaway data dir, run twice — once
/// killing at a batch boundary, once mid-group-commit with buffered
/// records still unsynced. Exits nonzero if any acknowledged job is
/// lost or recovery is slow — the CI `durability` job's gate.
fn cmd_serve_recover(
    args: &Args,
    shards: usize,
    cluster: usize,
    carbon: Vec<f64>,
    no_wal: bool,
) -> Result<()> {
    if no_wal {
        bail!("--selftest-recover needs durability; drop --no-wal");
    }
    const KILL_AFTER: usize = 100;
    let threads = args.usize("threads")?;
    for (mode, label) in [
        (KillMode::Boundary, "batch-boundary"),
        (KillMode::MidCommit, "mid-group-commit"),
    ] {
        let dir = ephemeral_data_dir(&format!("recover-{label}"));
        let _ = std::fs::remove_dir_all(&dir);
        println!(
            "kill-and-recover [{label}]: {shards} shards, {cluster} servers, \
             {threads} client threads, kill after {KILL_AFTER} acknowledged jobs ..."
        );
        let result = loadgen::kill_and_recover(
            shards,
            cluster,
            carbon.clone(),
            &dir,
            threads,
            KILL_AFTER,
            mode,
        );
        let _ = std::fs::remove_dir_all(&dir);
        let r = result?;
        println!(
            "acked {} jobs before the kill; recovery replayed {} events from {} WAL bytes \
             in {:.1} ms; {} lost",
            r.acked,
            r.replayed_events,
            r.wal_bytes,
            r.recovery.as_secs_f64() * 1e3,
            r.lost.len()
        );
        if r.acked < KILL_AFTER {
            bail!(
                "[{label}] scenario only acknowledged {} of {KILL_AFTER} jobs before its \
                 failsafe timeout",
                r.acked
            );
        }
        if !r.lost.is_empty() {
            let show: Vec<&str> = r.lost.iter().take(8).map(String::as_str).collect();
            bail!(
                "[{label}] durability violated: {} acknowledged jobs lost after recovery, \
                 e.g. {show:?}",
                r.lost.len()
            );
        }
        let limit = Duration::from_secs(10);
        if r.recovery > limit {
            bail!(
                "[{label}] recovery took {:.2} s (limit {:.0} s)",
                r.recovery.as_secs_f64(),
                limit.as_secs_f64()
            );
        }
        println!("kill-and-recover [{label}] OK: zero acknowledged jobs lost");
    }
    Ok(())
}

fn cmd_loadtest(rest: &[String]) -> Result<()> {
    const SPECS: &[ArgSpec] = &[
        ArgSpec::req("addr", "service address, e.g. 127.0.0.1:8080"),
        ArgSpec::opt("rps", "target requests per second", "50"),
        ArgSpec::opt("secs", "test duration in seconds", "10"),
        ArgSpec::opt("threads", "client threads", "4"),
        ArgSpec::opt("seed", "workload sampling seed", "1"),
        ArgSpec::opt("tenants", "distinct tenant ids", "64"),
        ArgSpec::opt("length", "job length in hours", "6"),
        ArgSpec::opt("slack", "completion factor T/l", "1.5"),
        ArgSpec::opt("max-servers", "job max servers M", "4"),
    ];
    let args = parse(rest, SPECS, "carbonscaler loadtest --addr <host:port>")?;
    let addr: std::net::SocketAddr = args
        .str("addr")?
        .parse()
        .map_err(|_| anyhow!("--addr must be ip:port"))?;
    let template = JobTemplate {
        length_hours: args.f64("length")?,
        slack: args.f64("slack")?,
        max_servers: args.usize("max-servers")?,
        tenants: args.usize("tenants")?,
        seed: args.u64("seed")?,
    };
    let gen = LoadGen::new(addr, args.usize("threads")?, template);
    let report = gen.paced(
        args.f64("rps")?,
        Duration::from_secs_f64(args.f64("secs")?),
    )?;
    print_load_report(&report);
    Ok(())
}

fn cmd_submit(rest: &[String]) -> Result<()> {
    const SPECS: &[ArgSpec] = &[
        ArgSpec::req("job", "path to a job spec JSON"),
        ArgSpec::opt("seed", "trace seed", "2023"),
    ];
    let args = parse(rest, SPECS, "carbonscaler submit --job <spec.json>")?;
    let req = api::load_job_request(&PathBuf::from(args.str("job")?))?;
    let trace = synthetic::generate(
        regions::by_name(&req.region).unwrap(),
        6 * 7 * 24,
        args.u64("seed")?,
    );
    let window = trace.window(req.spec.arrival, req.spec.n_slots());
    let plan = carbonscaler::sched::greedy::plan_polished(&req.spec, &window)?;
    println!(
        "schedule for {} (arrival h{}, deadline h{}):",
        req.spec.name,
        req.spec.arrival,
        req.spec.deadline()
    );
    let mut t = Table::new("").headers(&["slot", "carbon", "servers"]);
    for (i, &a) in plan.alloc.iter().enumerate() {
        t.row(vec![
            carbonscaler::util::timefmt::fmt_slot(req.spec.arrival + i),
            f(window[i], 0),
            a.to_string(),
        ]);
    }
    t.print();
    let rel = carbonscaler::carbon::CarbonTrace::new("w", window);
    let mut eval = plan.clone();
    eval.arrival = 0;
    println!(
        "planned emissions {:.1} g, completion {:.1} h, {} switches",
        eval.emissions_g(&req.spec, &rel),
        eval.completion_hours(&req.spec).unwrap_or(f64::NAN),
        plan.n_switches()
    );
    Ok(())
}
