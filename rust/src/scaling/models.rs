//! Parametric scaling models and Fig-2 workload presets.
//!
//! The paper's Fig 2 profiles six jobs (four DNN training jobs under
//! Horovod/PyTorch elastic, two MPI N-body sizes) with scaling behaviours
//! from near-linear to strongly bottlenecked. We model throughput-vs-
//! servers with Amdahl's law plus a per-server communication overhead
//! term, which reproduces all the observed shapes:
//!
//! `speedup(k) = 1 / (serial + (1-serial)/k + comm*(k-1))`... inverted to
//! throughput `T(k) = k_eff` — see [`amdahl_throughput`].

use crate::scaling::curve::MarginalCapacityCurve;

/// Throughput (relative to 1 server) of a job with serial fraction
/// `serial` and per-extra-server communication overhead `comm`, at `k`
/// servers. `serial = comm = 0` is perfectly linear.
pub fn amdahl_throughput(serial: f64, comm: f64, k: usize) -> f64 {
    assert!(k >= 1);
    let kf = k as f64;
    // Time per unit work relative to 1 server.
    let t = serial + (1.0 - serial) / kf + comm * (kf - 1.0);
    1.0 / t.max(1e-9)
}

/// Build a marginal capacity curve from the Amdahl+comm model, clamped to
/// be monotone non-increasing (at high k the comm term can make
/// throughput *decrease*; capacity is then flat — adding servers yields
/// nothing, which the greedy will simply never choose).
pub fn amdahl_curve(serial: f64, comm: f64, max_servers: usize) -> MarginalCapacityCurve {
    let mut thr = Vec::with_capacity(max_servers);
    let mut best: f64 = 0.0;
    for k in 1..=max_servers {
        best = best.max(amdahl_throughput(serial, comm, k));
        thr.push(best);
    }
    MarginalCapacityCurve::from_throughputs(&thr).expect("model curve is valid")
}

/// Scaling model parameters for one workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScalingModel {
    pub serial: f64,
    pub comm: f64,
}

impl ScalingModel {
    pub const fn new(serial: f64, comm: f64) -> Self {
        ScalingModel { serial, comm }
    }

    pub fn curve(&self, max_servers: usize) -> MarginalCapacityCurve {
        amdahl_curve(self.serial, self.comm, max_servers)
    }

    pub fn throughput(&self, k: usize) -> f64 {
        amdahl_throughput(self.serial, self.comm, k)
    }
}

/// Fig-2 presets (shape-matched to the paper's measurements):
/// * N-body 100k and ResNet18: near-linear up to 8 servers;
/// * N-body 10k: diminishing returns (communication-bound at small N);
/// * EfficientNetB1: moderate bottleneck;
/// * VGG16 / ResNet50: strong bottleneck (large parameter broadcasts).
pub mod presets {
    use super::ScalingModel;

    pub const NBODY_100K: ScalingModel = ScalingModel::new(0.003, 0.001);
    pub const NBODY_10K: ScalingModel = ScalingModel::new(0.06, 0.025);
    pub const RESNET18: ScalingModel = ScalingModel::new(0.008, 0.002);
    pub const EFFICIENTNET_B1: ScalingModel = ScalingModel::new(0.03, 0.012);
    pub const VGG16: ScalingModel = ScalingModel::new(0.08, 0.04);
    pub const RESNET50: ScalingModel = ScalingModel::new(0.06, 0.03);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_model_is_linear() {
        for k in 1..=8 {
            assert!((amdahl_throughput(0.0, 0.0, k) - k as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn serial_fraction_caps_speedup() {
        // Amdahl: speedup <= 1/serial.
        let s = amdahl_throughput(0.25, 0.0, 1000);
        assert!(s < 4.0);
        assert!(s > 3.9);
    }

    #[test]
    fn comm_overhead_can_cause_slowdown() {
        let t4 = amdahl_throughput(0.0, 0.2, 4);
        let t16 = amdahl_throughput(0.0, 0.2, 16);
        assert!(t16 < t4, "heavy comm should degrade at scale");
    }

    #[test]
    fn curves_monotone() {
        for m in [
            presets::NBODY_100K,
            presets::NBODY_10K,
            presets::RESNET18,
            presets::EFFICIENTNET_B1,
            presets::VGG16,
            presets::RESNET50,
        ] {
            let c = m.curve(64);
            assert!(c.is_monotone_decreasing());
            assert!((c.marginal(1) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn fig2_shape_ordering() {
        // At 8 servers: N-body(100k) ≈ ResNet18 > EfficientNet > VGG16;
        // N-body(10k) shows diminishing growth.
        let s8 = |m: ScalingModel| m.curve(8).speedup(8);
        assert!(s8(presets::NBODY_100K) > 7.0);
        assert!(s8(presets::RESNET18) > 6.5);
        assert!(s8(presets::EFFICIENTNET_B1) > 4.0 && s8(presets::EFFICIENTNET_B1) < 6.5);
        assert!(s8(presets::VGG16) < 4.5);
        assert!(s8(presets::NBODY_10K) < s8(presets::NBODY_100K));
    }

    #[test]
    fn preset_curves_are_normalized() {
        let c = presets::VGG16.curve(8);
        assert!((c.capacity(1) - 1.0).abs() < 1e-9);
    }
}
