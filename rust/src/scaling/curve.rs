//! Marginal capacity curves (paper §3.3, Fig 4).
//!
//! A [`MarginalCapacityCurve`] captures the incremental throughput gained
//! by each additional server: `mc[j]` is the extra (normalized) capacity
//! from the j-th server, j ∈ [1, M]. Linear scaling is a flat curve;
//! Amdahl-limited workloads have monotonically decreasing curves. The
//! curve is the sole scaling input to Algorithm 1.

use anyhow::{bail, Result};

/// Incremental capacity per added server.
#[derive(Debug, Clone, PartialEq)]
pub struct MarginalCapacityCurve {
    /// mc[0] is the marginal capacity of server 1 (normalized to 1.0 by
    /// convention), mc[j-1] of server j.
    mc: Vec<f64>,
    /// Prefix sums: cum[k] = capacity at k servers (cum[0] = 0). Kept so
    /// the schedule-accounting hot path gets O(1) capacity lookups.
    cum: Vec<f64>,
}

/// Internal constructor maintaining the prefix-sum invariant.
fn build(mc: Vec<f64>) -> MarginalCapacityCurve {
    let mut cum = Vec::with_capacity(mc.len() + 1);
    cum.push(0.0);
    let mut acc = 0.0;
    for &v in &mc {
        acc += v;
        cum.push(acc);
    }
    MarginalCapacityCurve { mc, cum }
}

impl MarginalCapacityCurve {
    /// Build from marginal increments directly.
    pub fn from_marginals(mc: Vec<f64>) -> Result<Self> {
        if mc.is_empty() {
            bail!("marginal capacity curve must cover at least one server");
        }
        if mc.iter().any(|&v| v < 0.0) {
            bail!("marginal capacity cannot be negative");
        }
        Ok(build(mc))
    }

    /// Build from cumulative throughput measurements `thr[j-1]` = jobs/hr
    /// at j servers (what the Carbon Profiler records). Normalizes so one
    /// server has capacity 1.0.
    pub fn from_throughputs(thr: &[f64]) -> Result<Self> {
        if thr.is_empty() {
            bail!("need at least one throughput sample");
        }
        if thr[0] <= 0.0 {
            bail!("single-server throughput must be positive");
        }
        let mut mc = Vec::with_capacity(thr.len());
        let mut prev = 0.0;
        for (j, &t) in thr.iter().enumerate() {
            if t < prev {
                bail!("throughput decreased at {} servers — curve must be non-decreasing", j + 1);
            }
            mc.push((t - prev) / thr[0]);
            prev = t;
        }
        Ok(build(mc))
    }

    /// Ideal linear scaling: flat curve of 1.0 (Fig 4a).
    pub fn linear(max_servers: usize) -> Self {
        build(vec![1.0; max_servers])
    }

    /// Maximum server count covered.
    pub fn max_servers(&self) -> usize {
        self.mc.len()
    }

    /// Marginal capacity of the j-th server (1-indexed).
    pub fn marginal(&self, j: usize) -> f64 {
        assert!(j >= 1 && j <= self.mc.len(), "server index {j} out of range");
        self.mc[j - 1]
    }

    /// Total capacity (relative throughput) at `k` servers: Σ_{j<=k} mc_j.
    /// k == 0 is a suspended job: zero capacity. O(1) via prefix sums.
    pub fn capacity(&self, k: usize) -> f64 {
        assert!(k <= self.mc.len(), "allocation {k} beyond curve");
        self.cum[k]
    }

    /// Speedup over one server at `k` servers.
    pub fn speedup(&self, k: usize) -> f64 {
        let base = self.capacity(1);
        if base <= 0.0 {
            return 0.0;
        }
        self.capacity(k) / base
    }

    /// True if strictly/weakly decreasing (the optimality precondition of
    /// Theorem 1; we accept ties).
    pub fn is_monotone_decreasing(&self) -> bool {
        self.mc.windows(2).all(|w| w[1] <= w[0] + 1e-12)
    }

    /// Enforce monotonicity by isotonic clipping (each marginal capped at
    /// the previous one). Profiling noise can produce small inversions;
    /// the paper's greedy requires a decreasing curve.
    pub fn monotonized(&self) -> Self {
        let mut mc = self.mc.clone();
        for j in 1..mc.len() {
            if mc[j] > mc[j - 1] {
                mc[j] = mc[j - 1];
            }
        }
        build(mc)
    }

    /// Interpolate a curve profiled at granularity β > 1 (paper §4.1): we
    /// have samples at server counts `ks` (ascending, first must be 1) and
    /// linearly interpolate cumulative capacity between them.
    pub fn interpolate(ks: &[usize], thr: &[f64], max_servers: usize) -> Result<Self> {
        if ks.len() != thr.len() || ks.is_empty() {
            bail!("ks/thr length mismatch or empty");
        }
        if ks[0] != 1 {
            bail!("profiling must include the 1-server point");
        }
        if !ks.windows(2).all(|w| w[0] < w[1]) {
            bail!("ks must be strictly ascending");
        }
        if *ks.last().unwrap() < max_servers {
            bail!("profiling must cover max_servers (or extrapolate explicitly)");
        }
        let mut cumulative = Vec::with_capacity(max_servers);
        for k in 1..=max_servers {
            // Find bracketing samples.
            let pos = ks.iter().position(|&s| s >= k).unwrap();
            let c = if ks[pos] == k || pos == 0 {
                thr[pos]
            } else {
                let (k0, k1) = (ks[pos - 1] as f64, ks[pos] as f64);
                let (t0, t1) = (thr[pos - 1], thr[pos]);
                t0 + (t1 - t0) * (k as f64 - k0) / (k1 - k0)
            };
            cumulative.push(c);
        }
        Self::from_throughputs(&cumulative)
    }

    /// Extrapolate the curve to a larger cluster (paper Fig 15: "we
    /// extrapolated the marginal capacity curve"): fit the tail decay rate
    /// and extend geometrically, clamped non-negative.
    pub fn extrapolate(&self, new_max: usize) -> Self {
        if new_max <= self.mc.len() {
            return build(self.mc[..new_max].to_vec());
        }
        let mut mc = self.mc.clone();
        // Geometric decay ratio estimated from the last few marginals.
        let n = mc.len();
        let tail = &mc[n.saturating_sub(4)..];
        let mut ratio = 1.0;
        let mut count = 0;
        for w in tail.windows(2) {
            if w[0] > 1e-9 {
                ratio += w[1] / w[0] - 1.0;
                count += 1;
            }
        }
        let r = if count > 0 {
            (1.0 + (ratio - 1.0) / count as f64).clamp(0.0, 1.0)
        } else {
            1.0
        };
        let mut last = *mc.last().unwrap();
        while mc.len() < new_max {
            last *= r;
            mc.push(last.max(0.0));
        }
        build(mc)
    }

    /// Apply multiplicative noise to each marginal (profiling-error model
    /// of §5.7 / Fig 21), re-monotonized.
    pub fn with_error(&self, error_frac: f64, rng: &mut crate::util::rng::Rng) -> Self {
        let mc = self
            .mc
            .iter()
            .map(|&v| (v * (1.0 + rng.range(-error_frac, error_frac))).max(0.0))
            .collect();
        build(mc).monotonized()
    }

    /// Raw marginals.
    pub fn marginals(&self) -> &[f64] {
        &self.mc
    }
}

/// A phase-dependent set of curves (paper §3.3: e.g. map vs reduce phases).
/// Phase boundaries are expressed as fractions of total work completed.
#[derive(Debug, Clone)]
pub struct PhasedCurve {
    /// (work-fraction upper bound, curve) pairs, ascending; last bound
    /// must be 1.0.
    phases: Vec<(f64, MarginalCapacityCurve)>,
}

impl PhasedCurve {
    pub fn single(curve: MarginalCapacityCurve) -> Self {
        PhasedCurve {
            phases: vec![(1.0, curve)],
        }
    }

    pub fn new(phases: Vec<(f64, MarginalCapacityCurve)>) -> Result<Self> {
        if phases.is_empty() {
            bail!("need at least one phase");
        }
        if (phases.last().unwrap().0 - 1.0).abs() > 1e-9 {
            bail!("last phase bound must be 1.0");
        }
        if !phases.windows(2).all(|w| w[0].0 < w[1].0) {
            bail!("phase bounds must be ascending");
        }
        Ok(PhasedCurve { phases })
    }

    /// Curve active when `done_frac` of the work is complete.
    pub fn at_progress(&self, done_frac: f64) -> &MarginalCapacityCurve {
        for (bound, curve) in &self.phases {
            if done_frac < *bound {
                return curve;
            }
        }
        &self.phases.last().unwrap().1
    }

    pub fn n_phases(&self) -> usize {
        self.phases.len()
    }

    /// The raw (work-fraction bound, curve) pairs. Exposed so external
    /// serializers (the pallas-serve WAL) can round-trip a job's scaling
    /// profile losslessly.
    pub fn phases(&self) -> &[(f64, MarginalCapacityCurve)] {
        &self.phases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_curve_flat() {
        let c = MarginalCapacityCurve::linear(4);
        assert_eq!(c.capacity(4), 4.0);
        assert_eq!(c.marginal(3), 1.0);
        assert!(c.is_monotone_decreasing());
    }

    #[test]
    fn from_throughputs_normalizes() {
        // 10, 18, 24 jobs/hr at 1..3 servers.
        let c = MarginalCapacityCurve::from_throughputs(&[10.0, 18.0, 24.0]).unwrap();
        assert!((c.marginal(1) - 1.0).abs() < 1e-12);
        assert!((c.marginal(2) - 0.8).abs() < 1e-12);
        assert!((c.marginal(3) - 0.6).abs() < 1e-12);
        assert!((c.speedup(3) - 2.4).abs() < 1e-12);
    }

    #[test]
    fn from_throughputs_rejects_decreasing() {
        assert!(MarginalCapacityCurve::from_throughputs(&[10.0, 8.0]).is_err());
        assert!(MarginalCapacityCurve::from_throughputs(&[0.0]).is_err());
    }

    #[test]
    fn capacity_zero_when_suspended() {
        let c = MarginalCapacityCurve::linear(4);
        assert_eq!(c.capacity(0), 0.0);
    }

    #[test]
    fn monotonize_fixes_inversions() {
        let c = MarginalCapacityCurve::from_marginals(vec![1.0, 0.5, 0.7]).unwrap();
        assert!(!c.is_monotone_decreasing());
        let m = c.monotonized();
        assert!(m.is_monotone_decreasing());
        assert_eq!(m.marginals(), &[1.0, 0.5, 0.5]);
    }

    #[test]
    fn interpolation_beta2() {
        // Samples at 1, 3, 5 servers; interpolate 2 and 4.
        let c =
            MarginalCapacityCurve::interpolate(&[1, 3, 5], &[10.0, 26.0, 34.0], 5).unwrap();
        // capacity at 2 = 18/10, at 4 = 30/10
        assert!((c.capacity(2) - 1.8).abs() < 1e-12);
        assert!((c.capacity(4) - 3.0).abs() < 1e-12);
        assert_eq!(c.max_servers(), 5);
    }

    #[test]
    fn interpolation_requires_coverage() {
        assert!(MarginalCapacityCurve::interpolate(&[1, 2], &[1.0, 1.8], 4).is_err());
        assert!(MarginalCapacityCurve::interpolate(&[2, 4], &[1.0, 1.8], 4).is_err());
    }

    #[test]
    fn extrapolate_decays() {
        let c = MarginalCapacityCurve::from_marginals(vec![1.0, 0.8, 0.64]).unwrap();
        let e = c.extrapolate(6);
        assert_eq!(e.max_servers(), 6);
        assert!(e.is_monotone_decreasing());
        // Ratio ~0.8 -> next marginal ~0.512.
        assert!((e.marginal(4) - 0.512).abs() < 0.02);
    }

    #[test]
    fn extrapolate_truncates() {
        let c = MarginalCapacityCurve::linear(8);
        assert_eq!(c.extrapolate(3).max_servers(), 3);
    }

    #[test]
    fn error_injection_stays_monotone() {
        let mut rng = crate::util::rng::Rng::new(4);
        let c = MarginalCapacityCurve::from_marginals(vec![1.0, 0.8, 0.6, 0.4]).unwrap();
        for _ in 0..50 {
            let e = c.with_error(0.3, &mut rng);
            assert!(e.is_monotone_decreasing());
            assert!(e.marginals().iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn phased_curve_selects_by_progress() {
        let map = MarginalCapacityCurve::linear(4);
        let reduce = MarginalCapacityCurve::from_marginals(vec![1.0, 0.2, 0.1, 0.05]).unwrap();
        let p = PhasedCurve::new(vec![(0.7, map.clone()), (1.0, reduce.clone())]).unwrap();
        assert_eq!(p.at_progress(0.0), &map);
        assert_eq!(p.at_progress(0.69), &map);
        assert_eq!(p.at_progress(0.7), &reduce);
        assert_eq!(p.at_progress(1.0), &reduce);
    }

    #[test]
    fn phased_curve_validation() {
        let c = MarginalCapacityCurve::linear(2);
        assert!(PhasedCurve::new(vec![]).is_err());
        assert!(PhasedCurve::new(vec![(0.5, c.clone())]).is_err());
        assert!(PhasedCurve::new(vec![(0.8, c.clone()), (0.4, c.clone()), (1.0, c)]).is_err());
    }
}
