//! Application scalability: marginal capacity curves and scaling models.

pub mod curve;
pub mod models;

pub use curve::{MarginalCapacityCurve, PhasedCurve};
pub use models::{amdahl_curve, amdahl_throughput, ScalingModel};
