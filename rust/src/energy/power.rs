//! Energy and carbon accounting (the RAPL/DCGM substitution).
//!
//! The paper measures per-server power with RAPL (CPU) and DCGM (GPU) and
//! reduces it to Table-1 constants (60 W CPU-only, 210 W CPU+GPU per
//! server). [`EnergyMeter`] integrates power over server-hours and charges
//! each hour at the *ground-truth* carbon intensity, yielding gCO₂eq
//! totals directly comparable to the paper's figures.

use crate::carbon::trace::CarbonTrace;

/// Energy (kWh) consumed by `servers` servers drawing `watts` each for
/// `hours`.
pub fn energy_kwh(servers: usize, watts: f64, hours: f64) -> f64 {
    servers as f64 * watts * hours / 1000.0
}

/// Carbon (gCO₂eq) for that energy at intensity `gco2_per_kwh`.
pub fn carbon_g(servers: usize, watts: f64, hours: f64, gco2_per_kwh: f64) -> f64 {
    energy_kwh(servers, watts, hours) * gco2_per_kwh
}

/// Accumulating meter for one job execution.
#[derive(Debug, Clone, Default)]
pub struct EnergyMeter {
    total_kwh: f64,
    total_gco2: f64,
    server_hours: f64,
    /// Per-slot (hour, servers, gCO₂) log for timelines (Fig 8).
    log: Vec<(usize, usize, f64)>,
}

impl EnergyMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `servers` × `watts` for `hours` within slot `slot` at the
    /// ground-truth intensity from `trace`.
    pub fn charge(
        &mut self,
        trace: &CarbonTrace,
        slot: usize,
        servers: usize,
        watts: f64,
        hours: f64,
    ) {
        let kwh = energy_kwh(servers, watts, hours);
        let g = kwh * trace.at(slot);
        self.total_kwh += kwh;
        self.total_gco2 += g;
        self.server_hours += servers as f64 * hours;
        self.log.push((slot, servers, g));
    }

    pub fn total_kwh(&self) -> f64 {
        self.total_kwh
    }

    /// Total emissions in gCO₂eq.
    pub fn total_gco2(&self) -> f64 {
        self.total_gco2
    }

    /// Total server-hours — the paper's monetary-cost proxy (§5.5 measures
    /// cost overhead as extra compute-hours).
    pub fn server_hours(&self) -> f64 {
        self.server_hours
    }

    pub fn slot_log(&self) -> &[(usize, usize, f64)] {
        &self.log
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_math() {
        // 2 servers * 210 W * 10 h = 4.2 kWh.
        assert!((energy_kwh(2, 210.0, 10.0) - 4.2).abs() < 1e-12);
        // At 100 g/kWh -> 420 g.
        assert!((carbon_g(2, 210.0, 10.0, 100.0) - 420.0).abs() < 1e-12);
    }

    #[test]
    fn meter_accumulates() {
        let trace = CarbonTrace::new("t", vec![100.0, 50.0]);
        let mut m = EnergyMeter::new();
        m.charge(&trace, 0, 1, 1000.0, 1.0); // 1 kWh @ 100 g
        m.charge(&trace, 1, 2, 1000.0, 0.5); // 1 kWh @ 50 g
        assert!((m.total_kwh() - 2.0).abs() < 1e-12);
        assert!((m.total_gco2() - 150.0).abs() < 1e-12);
        assert!((m.server_hours() - 2.0).abs() < 1e-12);
        assert_eq!(m.slot_log().len(), 2);
    }

    #[test]
    fn zero_servers_charge_nothing() {
        let trace = CarbonTrace::new("t", vec![500.0]);
        let mut m = EnergyMeter::new();
        m.charge(&trace, 0, 0, 210.0, 1.0);
        assert_eq!(m.total_gco2(), 0.0);
    }
}
