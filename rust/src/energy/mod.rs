//! Energy and carbon accounting.

pub mod power;

pub use power::{carbon_g, energy_kwh, EnergyMeter};
