//! Deterministic pseudo-random number generation.
//!
//! Built from scratch (no `rand` crate offline): a splitmix64-seeded
//! xoshiro256** generator. Every stochastic component in the library
//! (synthetic carbon traces, error injection, procurement denial, property
//! tests) takes an explicit [`Rng`] so that every experiment and test is
//! reproducible from a single seed, and independent streams can be forked
//! with [`Rng::fork`].

/// splitmix64: used to expand a single `u64` seed into generator state and
/// to derive independent child seeds.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG; fast, high-quality, and fully deterministic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. Identical seeds yield identical
    /// streams on every platform.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (used to give each region /
    /// worker / experiment its own stream without correlation).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (n > 0), rejection-sampled to avoid
    /// modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn int_range_inclusive_bounds() {
        let mut r = Rng::new(5);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            seen_lo |= v == -3;
            seen_hi |= v == 3;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(31);
        assert!(!(0..1000).any(|_| r.chance(0.0)));
        assert!((0..1000).all(|_| r.chance(1.0)));
    }
}
