//! Foundational utilities built from scratch for the offline environment:
//! deterministic RNG, statistics, JSON, CLI parsing, tables, and time types.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timefmt;
