//! Minimal, complete JSON parser and writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes
//! and \uXXXX, numbers, booleans, null). Object key order is preserved so
//! written files diff cleanly. Used for `artifacts/manifest.json`, job-spec
//! files (the Kubernetes-CRD analog), and experiment output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (Vec of pairs; lookups are linear, objects
    /// in this codebase are small).
    Obj(Vec<(String, Json)>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Nested lookup: `get_path(&["a", "b"])` == `self["a"]["b"]`.
    pub fn get_path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    /// Object fields as an ordered map of (key -> value) refs.
    pub fn entries(&self) -> BTreeMap<&str, &Json> {
        match self {
            Json::Obj(o) => o.iter().map(|(k, v)| (k.as_str(), v)).collect(),
            _ => BTreeMap::new(),
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut o) = self {
            o.push((key.to_string(), value.into()));
        }
        self
    }

    // -- serialization -----------------------------------------------------

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Compact serialization appended to a caller-owned buffer — lets
    /// hot paths (the service's response building) reuse one scratch
    /// string across serializations instead of growing a fresh one each
    /// time.
    pub fn write_compact_into(&self, out: &mut String) {
        self.write(out, None, 0);
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document. Trailing whitespace is allowed, trailing content
/// is an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Json::Null)
        );
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"x","vals":[1,2.5,true,null],"nested":{"k":"v"}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn key_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn builder_api() {
        let v = Json::obj()
            .set("n", 3usize)
            .set("s", "str")
            .set("arr", vec![1.0, 2.0]);
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("arr").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn get_path_nested() {
        let v = parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(v.get_path(&["a", "b", "c"]).unwrap().as_f64(), Some(7.0));
        assert_eq!(v.get_path(&["a", "x"]), None);
    }

    #[test]
    fn manifest_like_document() {
        // Shape of artifacts/manifest.json.
        let src = r#"{"artifacts":{"transformer_tiny":{"batch":4,"file":"t.hlo.txt","n_params":19712}},"format":"hlo-text"}"#;
        let v = parse(src).unwrap();
        assert_eq!(
            v.get_path(&["artifacts", "transformer_tiny", "n_params"])
                .unwrap()
                .as_usize(),
            Some(19712)
        );
    }
}
