//! Aligned plain-text table rendering for experiment output.
//!
//! Every `carbonscaler expt figN` command prints its rows through this so
//! the regenerated tables/figures are readable in a terminal and easy to
//! diff against EXPERIMENTS.md.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table {
            title: title.to_string(),
            headers: Vec::new(),
            rows: Vec::new(),
        }
    }

    pub fn headers(mut self, hs: &[&str]) -> Self {
        self.headers = hs.iter().map(|s| s.to_string()).collect();
        self
    }

    /// Append a row; panics if the width disagrees with the headers.
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        if !self.headers.is_empty() {
            assert_eq!(
                cells.len(),
                self.headers.len(),
                "row width != header width"
            );
        }
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn title(&self) -> &str {
        &self.title
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self
            .headers
            .len()
            .max(self.rows.iter().map(Vec::len).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }

        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
                .trim_end()
                .to_string()
        };
        if !self.headers.is_empty() {
            out.push_str(&fmt_row(&self.headers, &widths));
            out.push('\n');
            let total: usize = widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helper: fixed-decimals float.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Format helper: percentage with sign.
pub fn pct(x: f64) -> String {
    format!("{:+.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").headers(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "22".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].contains("demo"));
        assert!(lines[1].starts_with("name"));
        // column alignment: "value" column starts at same offset in all rows
        let off = lines[1].find("value").unwrap();
        assert_eq!(&lines[3][off..off + 1], "1");
        assert_eq!(&lines[4][off..off + 2], "22");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("x").headers(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.123), "+12.3%");
        assert_eq!(pct(-0.05), "-5.0%");
    }

    #[test]
    fn f_formats() {
        assert_eq!(f(3.14159, 2), "3.14");
    }

    #[test]
    fn empty_table() {
        let t = Table::new("empty").headers(&["a"]);
        assert!(t.is_empty());
        assert!(t.render().contains("empty"));
    }
}
