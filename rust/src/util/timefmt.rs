//! Simulation time types.
//!
//! All scheduling happens on a discretized hourly grid (the paper uses
//! hourly carbon intensity and hour-granularity slots; §3.4 notes 15-minute
//! slots work identically). `SlotIndex` counts slots since trace start;
//! `Hours` is a duration. Keeping these as newtypes prevents the classic
//! slot-vs-hour unit bugs in schedule arithmetic.

use std::fmt;
use std::ops::{Add, Sub};

/// Duration in (fractional) hours.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct Hours(pub f64);

impl Hours {
    pub fn as_secs(self) -> f64 {
        self.0 * 3600.0
    }

    pub fn from_secs(s: f64) -> Self {
        Hours(s / 3600.0)
    }
}

impl Add for Hours {
    type Output = Hours;
    fn add(self, rhs: Hours) -> Hours {
        Hours(self.0 + rhs.0)
    }
}

impl Sub for Hours {
    type Output = Hours;
    fn sub(self, rhs: Hours) -> Hours {
        Hours(self.0 - rhs.0)
    }
}

impl fmt::Display for Hours {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 48.0 {
            write!(f, "{:.1}d", self.0 / 24.0)
        } else {
            write!(f, "{:.1}h", self.0)
        }
    }
}

/// Index of a schedule slot (one slot = one hour by default).
pub type SlotIndex = usize;

/// Human formatting of an hour-of-trace as "d{day} {hh}:00".
pub fn fmt_slot(slot: SlotIndex) -> String {
    format!("d{} {:02}:00", slot / 24, slot % 24)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hours_arithmetic() {
        assert_eq!(Hours(1.5) + Hours(2.5), Hours(4.0));
        assert_eq!(Hours(5.0) - Hours(2.0), Hours(3.0));
        assert_eq!(Hours(2.0).as_secs(), 7200.0);
        assert_eq!(Hours::from_secs(1800.0), Hours(0.5));
    }

    #[test]
    fn display_switches_units() {
        assert_eq!(format!("{}", Hours(3.0)), "3.0h");
        assert_eq!(format!("{}", Hours(96.0)), "4.0d");
    }

    #[test]
    fn slot_formatting() {
        assert_eq!(fmt_slot(0), "d0 00:00");
        assert_eq!(fmt_slot(25), "d1 01:00");
    }
}
