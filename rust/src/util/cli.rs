//! Small command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and a generated usage string. Each
//! subcommand of the `carbonscaler` binary declares its options through
//! [`ArgSpec`] and parses with [`Args::parse`].

use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Declarative option specification, used for validation + usage text.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

impl ArgSpec {
    pub const fn flag(name: &'static str, help: &'static str) -> Self {
        ArgSpec {
            name,
            help,
            takes_value: false,
            default: None,
        }
    }

    pub const fn opt(name: &'static str, help: &'static str, default: &'static str) -> Self {
        ArgSpec {
            name,
            help,
            takes_value: true,
            default: Some(default),
        }
    }

    pub const fn req(name: &'static str, help: &'static str) -> Self {
        ArgSpec {
            name,
            help,
            takes_value: true,
            default: None,
        }
    }
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv` (not including the program/subcommand name) against a
    /// spec. Unknown `--options` are an error; `--help` yields the usage
    /// text as an Err so callers can print and exit.
    pub fn parse(argv: &[String], specs: &[ArgSpec], usage_head: &str) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(usage(specs, usage_head));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n{}", usage(specs, usage_head)))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| format!("--{name} requires a value"))?,
                    };
                    args.values.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        // Fill defaults.
        for spec in specs {
            if spec.takes_value && !args.values.contains_key(spec.name) {
                if let Some(d) = spec.default {
                    args.values.insert(spec.name.to_string(), d.to_string());
                }
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> Result<String> {
        self.get(name)
            .map(String::from)
            .ok_or_else(|| anyhow!("missing required --{name}"))
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        self.str(name)?
            .parse()
            .map_err(|_| anyhow!("--{name} must be a number"))
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        self.str(name)?
            .parse()
            .map_err(|_| anyhow!("--{name} must be a non-negative integer"))
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        self.str(name)?
            .parse()
            .map_err(|_| anyhow!("--{name} must be a non-negative integer"))
    }
}

/// Render usage text from specs.
pub fn usage(specs: &[ArgSpec], head: &str) -> String {
    let mut s = format!("{head}\n\noptions:\n");
    for spec in specs {
        let arg = if spec.takes_value {
            format!("--{} <v>", spec.name)
        } else {
            format!("--{}", spec.name)
        };
        let default = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  {arg:<24} {}{default}\n", spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    const SPECS: &[ArgSpec] = &[
        ArgSpec::opt("region", "cloud region", "ontario"),
        ArgSpec::req("job", "job name"),
        ArgSpec::flag("verbose", "chatty output"),
    ];

    #[test]
    fn parses_key_value_both_styles() {
        let a = Args::parse(&sv(&["--job", "nbody", "--region=iceland"]), SPECS, "t").unwrap();
        assert_eq!(a.str("job").unwrap(), "nbody");
        assert_eq!(a.str("region").unwrap(), "iceland");
    }

    #[test]
    fn defaults_applied() {
        let a = Args::parse(&sv(&["--job", "x"]), SPECS, "t").unwrap();
        assert_eq!(a.str("region").unwrap(), "ontario");
    }

    #[test]
    fn missing_required_is_error_at_access() {
        let a = Args::parse(&sv(&[]), SPECS, "t").unwrap();
        assert!(a.str("job").is_err());
    }

    #[test]
    fn flags_and_positionals() {
        let a = Args::parse(&sv(&["pos1", "--verbose", "pos2"]), SPECS, "t").unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Args::parse(&sv(&["--nope"]), SPECS, "t").is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = Args::parse(&sv(&["--help"]), SPECS, "mytool").unwrap_err();
        assert!(err.contains("mytool"));
        assert!(err.contains("--region"));
    }

    #[test]
    fn typed_accessors() {
        let specs = &[ArgSpec::opt("n", "count", "5"), ArgSpec::opt("x", "ratio", "1.5")];
        let a = Args::parse(&sv(&[]), specs, "t").unwrap();
        assert_eq!(a.usize("n").unwrap(), 5);
        assert_eq!(a.f64("x").unwrap(), 1.5);
    }

    #[test]
    fn value_with_equals_in_value() {
        let specs = &[ArgSpec::req("expr", "expression")];
        let a = Args::parse(&sv(&["--expr=a=b"]), specs, "t").unwrap();
        assert_eq!(a.str("expr").unwrap(), "a=b");
    }
}
