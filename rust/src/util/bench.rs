//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs harness=false binaries in benches/ which call
//! [`bench`]: warmup, then timed iterations, reporting mean/p50/p99 per
//! iteration. Deterministic workloads + enough iterations keep run-to-run
//! noise low; EXPERIMENTS.md §Perf records the numbers.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>7} iters  mean {:>12?}  p50 {:>12?}  p99 {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p99
        )
    }
}

/// Run `f` repeatedly: `warmup` untimed iterations, then timed iterations
/// until `budget` elapses (at least `min_iters`). Prints and returns the
/// stats. The closure should return something observable to prevent DCE —
/// its result is black-boxed here.
pub fn bench<T>(name: &str, warmup: usize, min_iters: usize, budget: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || (start.elapsed() < budget && samples.len() < 1_000_000) {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
        if start.elapsed() >= budget && samples.len() >= min_iters {
            break;
        }
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[samples.len() / 2],
        p99: samples[(samples.len() * 99 / 100).min(samples.len() - 1)],
    };
    println!("{}", res.report());
    res
}

/// Opaque value sink (stable black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 2, 10, Duration::from_millis(20), || 1 + 1);
        assert!(r.iters >= 10);
        assert!(r.p50 <= r.p99);
        assert!(r.report().contains("noop"));
    }
}
