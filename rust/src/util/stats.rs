//! Descriptive statistics used across the advisor, experiments and benches.
//!
//! Everything operates on `&[f64]` and is written from scratch (no external
//! stats crates are available offline). All quantile computations use the
//! nearest-rank-with-linear-interpolation definition (type 7, numpy
//! default) so figures match what the paper's matplotlib pipeline computed.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (std/mean) — the paper's region-variability
/// metric (Figs 7, 18). Returns 0.0 when the mean is ~0 (e.g. Iceland).
pub fn coeff_of_variation(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m.abs() < 1e-12 {
        return 0.0;
    }
    std_dev(xs) / m
}

/// Linear-interpolated percentile, `p` in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice (avoids the sort per call when
/// sweeping many percentiles).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum; +inf for an empty slice.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum; -inf for an empty slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Pearson correlation coefficient (Fig 18a reports 0.82 between savings
/// and coefficient of variation). Returns 0.0 when either side is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson: length mismatch");
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx).powi(2);
        vy += (y - my).powi(2);
    }
    if vx < 1e-300 || vy < 1e-300 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Empirical CDF evaluation points: returns (sorted values, cumulative
/// fraction at each value). Used by the Fig 18(b) savings-CDF experiment.
pub fn ecdf(xs: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in ecdf input"));
    let n = v.len() as f64;
    let fracs = (1..=v.len()).map(|i| i as f64 / n).collect();
    (v, fracs)
}

/// Simple online mean/min/max/std accumulator for streaming metrics.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Welford update.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std_dev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn std_dev_known() {
        // Population std of [2,4,4,4,5,5,7,9] is 2.
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cov_zero_mean() {
        assert_eq!(coeff_of_variation(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn cov_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((coeff_of_variation(&xs) - 2.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn median_even() {
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn ecdf_monotone() {
        let (vals, fracs) = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
        assert_eq!(fracs.last().copied(), Some(1.0));
        assert!(fracs.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn accumulator_matches_batch() {
        let xs = [1.0, 5.0, 2.0, 8.0, 3.0];
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.add(x);
        }
        assert!((acc.mean() - mean(&xs)).abs() < 1e-12);
        assert!((acc.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(acc.min(), 1.0);
        assert_eq!(acc.max(), 8.0);
        assert_eq!(acc.count(), 5);
    }
}
