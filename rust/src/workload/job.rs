//! Job specification (the paper's §3.2 problem parameters).
//!
//! A job arrives at time `t` with minimum servers `m`, maximum `M`,
//! estimated length `l` (hours on `m` servers), and a desired completion
//! time `T >= t + l`. `T - (t + l)` is the slack; `T = t + l` means
//! on-time completion with zero temporal flexibility.

use crate::scaling::curve::PhasedCurve;
use anyhow::{bail, Result};

/// Parameters of one elastic batch job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Human-readable name.
    pub name: String,
    /// Arrival hour (slot index into the carbon trace).
    pub arrival: usize,
    /// Minimum servers m >= 1.
    pub min_servers: usize,
    /// Maximum servers M >= m.
    pub max_servers: usize,
    /// Estimated length in hours when running on `min_servers`.
    pub length_hours: f64,
    /// Desired completion time as hours after arrival; must be >= length.
    pub completion_hours: f64,
    /// Scalability profile (possibly phase-dependent).
    pub curve: PhasedCurve,
    /// Per-server power draw in watts (Table 1).
    pub power_watts: f64,
}

impl JobSpec {
    /// Validate invariant relationships; call after construction.
    pub fn validate(&self) -> Result<()> {
        if self.min_servers < 1 {
            bail!("m must be >= 1");
        }
        if self.max_servers < self.min_servers {
            bail!("M must be >= m");
        }
        if self.length_hours <= 0.0 {
            bail!("job length must be positive");
        }
        if self.completion_hours < self.length_hours {
            bail!(
                "completion time {} < job length {} — infeasible",
                self.completion_hours,
                self.length_hours
            );
        }
        let c = self.curve.at_progress(0.0);
        if c.max_servers() < self.max_servers {
            bail!(
                "capacity curve covers {} servers < M = {}",
                c.max_servers(),
                self.max_servers
            );
        }
        if self.power_watts <= 0.0 {
            bail!("power must be positive");
        }
        Ok(())
    }

    /// Total work in capacity-hours: W = l * capacity(m)  (§3.4).
    pub fn total_work(&self) -> f64 {
        self.length_hours * self.curve.at_progress(0.0).capacity(self.min_servers)
    }

    /// Number of slots in the scheduling window [arrival, arrival + T).
    pub fn n_slots(&self) -> usize {
        self.completion_hours.ceil() as usize
    }

    /// Slack hours: T - l.
    pub fn slack(&self) -> f64 {
        self.completion_hours - self.length_hours
    }

    /// Deadline as an absolute hour.
    pub fn deadline(&self) -> usize {
        self.arrival + self.n_slots()
    }
}

/// How the builder resolves the completion time `T` at build().
#[derive(Debug, Clone, Copy)]
enum Completion {
    /// T = l (on-time, zero slack) — the paper's default.
    OnTime,
    /// T = factor × l (the paper's "T = 1.5 × l" notation).
    Factor(f64),
    /// Absolute hours after arrival.
    Hours(f64),
}

/// Convenience builder for the common single-phase case. Option order is
/// irrelevant: completion is resolved against the final length at build().
pub struct JobBuilder {
    spec: JobSpec,
    completion: Completion,
}

impl JobBuilder {
    pub fn new(name: &str, curve: crate::scaling::MarginalCapacityCurve) -> Self {
        let max = curve.max_servers();
        JobBuilder {
            spec: JobSpec {
                name: name.to_string(),
                arrival: 0,
                min_servers: 1,
                max_servers: max,
                length_hours: 24.0,
                completion_hours: 24.0,
                curve: PhasedCurve::single(curve),
                power_watts: 210.0,
            },
            completion: Completion::OnTime,
        }
    }

    pub fn arrival(mut self, h: usize) -> Self {
        self.spec.arrival = h;
        self
    }

    pub fn servers(mut self, m: usize, max: usize) -> Self {
        self.spec.min_servers = m;
        self.spec.max_servers = max;
        self
    }

    pub fn length(mut self, hours: f64) -> Self {
        self.spec.length_hours = hours;
        self
    }

    /// Set completion time as a multiple of job length (the paper's
    /// "T = 1.5 × l" notation).
    pub fn slack_factor(mut self, factor: f64) -> Self {
        self.completion = Completion::Factor(factor);
        self
    }

    pub fn completion(mut self, hours: f64) -> Self {
        self.completion = Completion::Hours(hours);
        self
    }

    pub fn power(mut self, watts: f64) -> Self {
        self.spec.power_watts = watts;
        self
    }

    pub fn phased(mut self, curve: PhasedCurve) -> Self {
        self.spec.curve = curve;
        self
    }

    pub fn build(mut self) -> Result<JobSpec> {
        self.spec.completion_hours = match self.completion {
            Completion::OnTime => self.spec.length_hours,
            Completion::Factor(f) => self.spec.length_hours * f,
            Completion::Hours(h) => h,
        };
        self.spec.validate()?;
        Ok(self.spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scaling::MarginalCapacityCurve;

    fn linear_job() -> JobSpec {
        JobBuilder::new("j", MarginalCapacityCurve::linear(4))
            .length(10.0)
            .slack_factor(1.5)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_defaults_valid() {
        let j = JobBuilder::new("x", MarginalCapacityCurve::linear(8))
            .build()
            .unwrap();
        assert_eq!(j.min_servers, 1);
        assert_eq!(j.max_servers, 8);
        assert_eq!(j.slack(), 0.0);
    }

    #[test]
    fn total_work_scales_with_min_servers() {
        let j = linear_job();
        assert_eq!(j.total_work(), 10.0); // m=1, capacity 1
        let j2 = JobBuilder::new("j", MarginalCapacityCurve::linear(8))
            .servers(2, 8)
            .length(10.0)
            .build()
            .unwrap();
        assert_eq!(j2.total_work(), 20.0); // m=2, capacity 2
    }

    #[test]
    fn slots_and_deadline() {
        let j = linear_job();
        assert_eq!(j.n_slots(), 15);
        assert_eq!(j.deadline(), 15);
        assert_eq!(j.slack(), 5.0);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        assert!(JobBuilder::new("x", MarginalCapacityCurve::linear(4))
            .servers(0, 4)
            .build()
            .is_err());
        assert!(JobBuilder::new("x", MarginalCapacityCurve::linear(4))
            .servers(5, 4)
            .build()
            .is_err());
        assert!(JobBuilder::new("x", MarginalCapacityCurve::linear(4))
            .servers(1, 8) // curve only covers 4
            .build()
            .is_err());
        assert!(JobBuilder::new("x", MarginalCapacityCurve::linear(4))
            .length(10.0)
            .completion(5.0)
            .build()
            .is_err());
    }
}
