//! Table 1 workload catalog.
//!
//! The paper evaluates five elastic workloads; each entry records the
//! implementation class, the epochs needed for a 24 h job at one server,
//! the per-server power draw, and the Fig-2 scaling model. These drive
//! the advisor-mode experiments; the `real` execution mode instead runs
//! PJRT-backed analogs (transformer training / N-body) via
//! [`crate::runtime`].

use crate::scaling::models::{presets, ScalingModel};
use crate::workload::job::{JobBuilder, JobSpec};
use anyhow::Result;

/// Implementation framework (informational, mirrors Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    Mpi,
    Pytorch,
}

/// One Table-1 row.
#[derive(Debug, Clone)]
pub struct WorkloadInfo {
    pub name: &'static str,
    pub framework: Framework,
    /// Epochs for a 24 h single-server job (Table 1).
    pub epochs_24h: u64,
    /// Batch size (None for MPI jobs).
    pub batch_size: Option<u32>,
    /// Per-server power in watts (Table 1: CPU 60 W, CPU+GPU 210 W).
    pub power_watts: f64,
    /// Fig-2 scaling model.
    pub scaling: ScalingModel,
}

/// The five Table-1 workloads.
pub const WORKLOADS: &[WorkloadInfo] = &[
    WorkloadInfo {
        name: "nbody-10k",
        framework: Framework::Mpi,
        epochs_24h: 138_000,
        batch_size: None,
        power_watts: 60.0,
        scaling: presets::NBODY_10K,
    },
    WorkloadInfo {
        name: "nbody-100k",
        framework: Framework::Mpi,
        epochs_24h: 1_500,
        batch_size: None,
        power_watts: 60.0,
        scaling: presets::NBODY_100K,
    },
    WorkloadInfo {
        name: "resnet18",
        framework: Framework::Pytorch,
        epochs_24h: 173,
        batch_size: Some(256),
        power_watts: 210.0,
        scaling: presets::RESNET18,
    },
    WorkloadInfo {
        name: "efficientnet-b1",
        framework: Framework::Pytorch,
        epochs_24h: 45,
        batch_size: Some(96),
        power_watts: 210.0,
        scaling: presets::EFFICIENTNET_B1,
    },
    WorkloadInfo {
        name: "vgg16",
        framework: Framework::Pytorch,
        epochs_24h: 31,
        batch_size: Some(96),
        power_watts: 210.0,
        scaling: presets::VGG16,
    },
];

/// Look up a workload by name.
pub fn by_name(name: &str) -> Option<&'static WorkloadInfo> {
    WORKLOADS.iter().find(|w| w.name == name)
}

/// Names of all Table-1 workloads.
pub fn names() -> Vec<&'static str> {
    WORKLOADS.iter().map(|w| w.name).collect()
}

impl WorkloadInfo {
    /// Build a JobSpec for this workload with the standard evaluation
    /// setup (m=1, M=`max_servers`, given length and slack factor).
    pub fn job(
        &self,
        arrival: usize,
        length_hours: f64,
        slack_factor: f64,
        max_servers: usize,
    ) -> Result<JobSpec> {
        JobBuilder::new(self.name, self.scaling.curve(max_servers))
            .arrival(arrival)
            .servers(1, max_servers)
            .length(length_hours)
            .slack_factor(slack_factor)
            .power(self.power_watts)
            .build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_workloads_as_table1() {
        assert_eq!(WORKLOADS.len(), 5);
    }

    #[test]
    fn table1_values() {
        let r18 = by_name("resnet18").unwrap();
        assert_eq!(r18.epochs_24h, 173);
        assert_eq!(r18.batch_size, Some(256));
        assert_eq!(r18.power_watts, 210.0);
        let nb = by_name("nbody-100k").unwrap();
        assert_eq!(nb.epochs_24h, 1_500);
        assert_eq!(nb.power_watts, 60.0);
        assert_eq!(nb.batch_size, None);
    }

    #[test]
    fn job_construction_all_workloads() {
        for w in WORKLOADS {
            let j = w.job(0, 24.0, 1.5, 8).unwrap();
            assert_eq!(j.max_servers, 8);
            assert_eq!(j.total_work(), 24.0);
            assert_eq!(j.power_watts, w.power_watts);
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("nope").is_none());
    }
}
