//! Workload definitions: job specifications, the Table-1 catalog, and
//! the interactive (latency-SLO) request-stream class.

pub mod catalog;
pub mod interactive;
pub mod job;

pub use catalog::{WorkloadInfo, WORKLOADS};
pub use interactive::{coord_of, rtt_ms, RegionCoord, ServiceSpec};
pub use job::{JobBuilder, JobSpec};
