//! Workload definitions: job specifications and the Table-1 catalog.

pub mod catalog;
pub mod job;

pub use catalog::{WorkloadInfo, WORKLOADS};
pub use job::{JobBuilder, JobSpec};
