//! Interactive (latency-SLO) workload class: per-region diurnal request
//! streams with latency floors derived from inter-region RTTs.
//!
//! CarbonScaler schedules only delay-tolerant batch jobs; CASPER
//! (PAPERS.md) shows that latency-sensitive web services can also be
//! carbon-aware, by routing requests to greener regions *within* the
//! service's latency SLO. This module models the demand side of that
//! story over the same 37-region catalog the batch planners use:
//!
//! * a coordinate table for every catalog region and a great-circle RTT
//!   model between them ([`rtt_ms`]), giving each (home, serving) region
//!   pair a latency floor no routing policy can beat;
//! * [`ServiceSpec`]: a registered request stream — home region, latency
//!   SLO, diurnal demand curve in *server* units (requests are already
//!   converted to servers via the service's provisioning ratio, so the
//!   scheduler trades in the same capacity units as batch jobs).
//!
//! SUBSTITUTION (see DESIGN.md §3/§15): the paper's ecosystem (CASPER)
//! measures real inter-region RTTs and request traces; neither is
//! reachable here. RTTs are synthesized from great-circle distance at
//! effective fiber propagation speed (~200 km/ms one-way, i.e. ~1 ms RTT
//! per 100 km) plus a 2 ms stack overhead — matching published
//! cloud-ping orders of magnitude — and demand is a deterministic
//! sinusoid peaking mid-afternoon *local* time (timezone from the home
//! region's longitude) with seeded multiplicative jitter. Real RTT
//! matrices or request traces drop in without touching the planner.

use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Geographic coordinates of one catalog region (metro-area centroid).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RegionCoord {
    /// Catalog name, matching [`crate::carbon::regions::REGIONS`].
    pub name: &'static str,
    /// Latitude, degrees north.
    pub lat: f64,
    /// Longitude, degrees east.
    pub lon: f64,
}

/// Coordinates for all 37 catalog regions (same order as the catalog is
/// not required; lookups go by name — coverage is asserted in tests).
pub const COORDS: &[RegionCoord] = &[
    RegionCoord { name: "ontario", lat: 43.7, lon: -79.4 },
    RegionCoord { name: "netherlands", lat: 52.4, lon: 4.9 },
    RegionCoord { name: "california", lat: 34.1, lon: -118.2 },
    RegionCoord { name: "iceland", lat: 64.1, lon: -21.9 },
    RegionCoord { name: "india", lat: 28.6, lon: 77.2 },
    RegionCoord { name: "singapore", lat: 1.4, lon: 103.8 },
    RegionCoord { name: "sweden", lat: 65.6, lon: 22.2 },
    RegionCoord { name: "quebec", lat: 46.8, lon: -71.2 },
    RegionCoord { name: "oregon", lat: 45.8, lon: -119.7 },
    RegionCoord { name: "virginia", lat: 39.0, lon: -77.5 },
    RegionCoord { name: "ohio", lat: 40.0, lon: -83.0 },
    RegionCoord { name: "texas", lat: 32.8, lon: -96.8 },
    RegionCoord { name: "ireland", lat: 53.3, lon: -6.3 },
    RegionCoord { name: "london", lat: 51.5, lon: -0.1 },
    RegionCoord { name: "frankfurt", lat: 50.1, lon: 8.7 },
    RegionCoord { name: "paris", lat: 48.9, lon: 2.4 },
    RegionCoord { name: "milan", lat: 45.5, lon: 9.2 },
    RegionCoord { name: "stockholm", lat: 59.3, lon: 18.1 },
    RegionCoord { name: "zurich", lat: 47.4, lon: 8.5 },
    RegionCoord { name: "spain", lat: 40.4, lon: -3.7 },
    RegionCoord { name: "warsaw", lat: 52.2, lon: 21.0 },
    RegionCoord { name: "tokyo", lat: 35.7, lon: 139.7 },
    RegionCoord { name: "osaka", lat: 34.7, lon: 135.5 },
    RegionCoord { name: "seoul", lat: 37.6, lon: 127.0 },
    RegionCoord { name: "mumbai", lat: 19.1, lon: 72.9 },
    RegionCoord { name: "hyderabad", lat: 17.4, lon: 78.5 },
    RegionCoord { name: "jakarta", lat: -6.2, lon: 106.8 },
    RegionCoord { name: "sydney", lat: -33.9, lon: 151.2 },
    RegionCoord { name: "melbourne", lat: -37.8, lon: 145.0 },
    RegionCoord { name: "saopaulo", lat: -23.6, lon: -46.6 },
    RegionCoord { name: "capetown", lat: -33.9, lon: 18.4 },
    RegionCoord { name: "bahrain", lat: 26.2, lon: 50.6 },
    RegionCoord { name: "uae", lat: 25.2, lon: 55.3 },
    RegionCoord { name: "telaviv", lat: 32.1, lon: 34.8 },
    RegionCoord { name: "montreal", lat: 45.5, lon: -73.6 },
    RegionCoord { name: "calgary", lat: 51.0, lon: -114.1 },
    RegionCoord { name: "norcal", lat: 37.8, lon: -122.4 },
];

/// Look up a region's coordinates by catalog name.
pub fn coord_of(name: &str) -> Option<&'static RegionCoord> {
    COORDS.iter().find(|c| c.name == name)
}

/// Great-circle distance between two coordinates, km (haversine).
pub fn dist_km(a: &RegionCoord, b: &RegionCoord) -> f64 {
    const EARTH_RADIUS_KM: f64 = 6371.0;
    let (la1, la2) = (a.lat.to_radians(), b.lat.to_radians());
    let dla = (b.lat - a.lat).to_radians();
    let dlo = (b.lon - a.lon).to_radians();
    let h = (dla / 2.0).sin().powi(2) + la1.cos() * la2.cos() * (dlo / 2.0).sin().powi(2);
    2.0 * EARTH_RADIUS_KM * h.sqrt().asin()
}

/// Modeled round-trip time between two catalog regions, milliseconds:
/// 2 ms stack overhead + great-circle propagation at ~200 km/ms each
/// way. Same-region RTT is therefore 2 ms — every positive SLO of at
/// least that much admits serving at home. `None` if either name is
/// missing from [`COORDS`].
pub fn rtt_ms(a: &str, b: &str) -> Option<f64> {
    let (ca, cb) = (coord_of(a)?, coord_of(b)?);
    Some(2.0 + 2.0 * dist_km(ca, cb) / 200.0)
}

/// A registered interactive service: a request stream anchored at a home
/// region, with a latency SLO bounding which regions may serve it.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceSpec {
    /// Service identifier (unique within a deployment).
    pub name: String,
    /// Home region (catalog name): where the users are.
    pub home: String,
    /// Latency SLO, ms: a region may serve this stream only if
    /// `rtt_ms(home, region) <= slo_ms`.
    pub slo_ms: f64,
    /// Diurnal peak demand, in servers (requests/s already divided by the
    /// service's per-server throughput).
    pub peak_servers: usize,
    /// First active slot (absolute hour).
    pub arrival: usize,
    /// Active duration, slots.
    pub hours: usize,
    /// Per-server draw at full load, watts (carbon accounting).
    pub power_watts: f64,
}

impl ServiceSpec {
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("service name empty");
        }
        if coord_of(&self.home).is_none() {
            bail!("service {}: unknown home region {:?}", self.name, self.home);
        }
        if !(self.slo_ms.is_finite() && self.slo_ms > 0.0) {
            bail!("service {}: non-positive SLO {}", self.name, self.slo_ms);
        }
        if self.peak_servers == 0 {
            bail!("service {}: zero peak demand", self.name);
        }
        if self.hours == 0 {
            bail!("service {}: zero duration", self.name);
        }
        if !(self.power_watts.is_finite() && self.power_watts > 0.0) {
            bail!("service {}: bad power {}", self.name, self.power_watts);
        }
        Ok(())
    }

    /// Per-slot demand in servers over `[arrival, arrival + hours)`:
    /// a diurnal sinusoid peaking at 15:00 *local* time (timezone from
    /// the home longitude, 15°/h), trough at 30 % of peak, with ±5 %
    /// seeded multiplicative jitter. Deterministic in (spec, seed).
    pub fn demand(&self, seed: u64) -> Vec<usize> {
        let tz = (coord_of(&self.home).map_or(0.0, |c| c.lon) / 15.0).round() as i64;
        let mut rng = Rng::new(seed).fork(crate::service::wal::checksum(self.name.as_bytes()));
        (0..self.hours)
            .map(|t| {
                let local = (self.arrival as i64 + t as i64 + tz).rem_euclid(24) as f64;
                let day = 0.5 * (1.0 + (std::f64::consts::TAU * (local - 15.0) / 24.0).cos());
                let base = self.peak_servers as f64 * (0.3 + 0.7 * day);
                (base * rng.range(0.95, 1.05)).ceil() as usize
            })
            .collect()
    }

    /// Slot one past the last active one.
    pub fn end(&self) -> usize {
        self.arrival + self.hours
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::regions;

    #[test]
    fn coords_cover_the_whole_catalog_exactly() {
        assert_eq!(COORDS.len(), regions::REGIONS.len());
        for r in regions::REGIONS {
            assert!(coord_of(r.name).is_some(), "no coordinates for {}", r.name);
        }
    }

    #[test]
    fn rtt_is_symmetric_zero_based_and_triangleish() {
        assert!((rtt_ms("tokyo", "tokyo").unwrap() - 2.0).abs() < 1e-9);
        let ab = rtt_ms("london", "sydney").unwrap();
        let ba = rtt_ms("sydney", "london").unwrap();
        assert!((ab - ba).abs() < 1e-9);
        // Nearby pairs are fast, antipodal pairs are slow.
        assert!(rtt_ms("tokyo", "osaka").unwrap() < 10.0);
        assert!(rtt_ms("london", "sydney").unwrap() > 100.0);
        assert!(rtt_ms("nowhere", "tokyo").is_none());
    }

    fn spec() -> ServiceSpec {
        ServiceSpec {
            name: "web".into(),
            home: "virginia".into(),
            slo_ms: 50.0,
            peak_servers: 8,
            arrival: 0,
            hours: 48,
            power_watts: 210.0,
        }
    }

    #[test]
    fn demand_is_diurnal_bounded_and_deterministic() {
        let s = spec();
        s.validate().unwrap();
        let d = s.demand(7);
        assert_eq!(d.len(), 48);
        assert_eq!(d, s.demand(7), "same seed must reproduce");
        let peak = *d.iter().max().unwrap();
        let trough = *d.iter().min().unwrap();
        assert!(peak <= (s.peak_servers as f64 * 1.05).ceil() as usize);
        assert!(trough >= 1, "trough floor keeps the service warm");
        assert!(trough < peak, "curve must actually be diurnal");
        // The two days repeat in shape (same local hours), modulo jitter.
        let day_gap: i64 = (0..24).map(|t| d[t] as i64 - d[t + 24] as i64).sum();
        assert!(day_gap.abs() <= 24, "days diverge beyond jitter: {day_gap}");
    }

    #[test]
    fn validate_rejects_bad_specs() {
        for bad in [
            ServiceSpec { name: "".into(), ..spec() },
            ServiceSpec { home: "atlantis".into(), ..spec() },
            ServiceSpec { slo_ms: 0.0, ..spec() },
            ServiceSpec { peak_servers: 0, ..spec() },
            ServiceSpec { hours: 0, ..spec() },
            ServiceSpec { power_watts: f64::NAN, ..spec() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should fail");
        }
    }
}
