//! N-body runtime: the MPI-workload analog driven through PJRT.
//!
//! One [`NBodySim`] owns the particle state and advances it by executing
//! the AOT-compiled leapfrog step. Elastic execution runs an *ensemble*
//! of independent replicas (one per active worker thread would mirror the
//! transformer pool; here replicas advance round-robin on one engine,
//! which is sufficient for progress/energy accounting in examples — the
//! measured-scaling path uses the transformer pool).

use crate::runtime::pjrt::{self, Engine, NBodyArtifact};
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// A running N-body simulation bound to a PJRT engine.
pub struct NBodySim {
    engine: Engine,
    n: usize,
    pos: Vec<f32>,
    vel: Vec<f32>,
    masses: Vec<f32>,
    steps: u64,
}

impl NBodySim {
    /// Load the artifact and draw Plummer-ish initial conditions
    /// (deterministic in `seed`, matching python/compile/model.py's
    /// init_nbody in distribution).
    pub fn new(art: &NBodyArtifact, seed: u64) -> Result<NBodySim> {
        let engine = Engine::load(&art.file)?;
        let n = art.n_bodies;
        let mut rng = Rng::new(seed);
        let pos: Vec<f32> = (0..3 * n).map(|_| rng.normal() as f32).collect();
        let vel: Vec<f32> = (0..3 * n).map(|_| 0.1 * rng.normal() as f32).collect();
        let masses: Vec<f32> = (0..n)
            .map(|_| ((rng.normal().abs() + 0.5) / n as f64) as f32)
            .collect();
        Ok(NBodySim {
            engine,
            n,
            pos,
            vel,
            masses,
            steps: 0,
        })
    }

    pub fn n_bodies(&self) -> usize {
        self.n
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn positions(&self) -> &[f32] {
        &self.pos
    }

    /// Advance one leapfrog step of size `dt`.
    pub fn step(&mut self, dt: f32) -> Result<()> {
        let n = self.n as i64;
        let inputs = vec![
            pjrt::literal_f32(&self.pos, &[n, 3])?,
            pjrt::literal_f32(&self.vel, &[n, 3])?,
            pjrt::literal_f32(&self.masses, &[n])?,
            pjrt::literal_scalar_f32(dt),
        ];
        let outs = self.engine.execute(&inputs)?;
        if outs.len() != 2 {
            bail!("expected (pos, vel), got {} outputs", outs.len());
        }
        self.pos = pjrt::to_vec_f32(&outs[0])?;
        self.vel = pjrt::to_vec_f32(&outs[1])?;
        self.steps += 1;
        Ok(())
    }

    /// Kinetic energy (sanity metric for examples).
    pub fn kinetic_energy(&self) -> f64 {
        let mut ke = 0.0;
        for i in 0..self.n {
            let m = self.masses[i] as f64;
            let v2: f64 = (0..3)
                .map(|d| {
                    let v = self.vel[3 * i + d] as f64;
                    v * v
                })
                .sum();
            ke += 0.5 * m * v2;
        }
        ke
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pjrt::Manifest;
    use std::path::PathBuf;

    #[test]
    fn nbody_steps_advance_state() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let Ok(m) = Manifest::load(&dir) else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let art = m.nbody("tiny").unwrap();
        let mut sim = NBodySim::new(art, 3).unwrap();
        let p0 = sim.positions().to_vec();
        sim.step(0.01).unwrap();
        sim.step(0.01).unwrap();
        assert_eq!(sim.steps(), 2);
        assert_ne!(sim.positions(), &p0[..]);
        assert!(sim.positions().iter().all(|v| v.is_finite()));
        assert!(sim.kinetic_energy() > 0.0);
    }
}
