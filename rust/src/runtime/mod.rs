//! PJRT runtime: artifact loading, elastic worker pool, parameter server.
//!
//! Python never runs here — artifacts are AOT-compiled HLO text produced
//! once by `make artifacts`.

pub mod nbody;
pub mod params;
pub mod pjrt;
pub mod worker;

pub use params::ParamServer;
pub use pjrt::{Engine, Manifest};
pub use worker::WorkerPool;
