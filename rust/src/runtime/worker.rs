//! Elastic worker pool: the data-parallel training substrate.
//!
//! Each worker is an OS thread owning its *own* PJRT CPU client and
//! compiled train-step executable (the xla crate's client is `Rc`-backed,
//! and one-runtime-per-worker mirrors real distributed replicas). The
//! leader broadcasts parameters, each active worker computes gradients on
//! its own deterministic microbatch shard, and the leader averages and
//! applies SGD ([`crate::runtime::params::ParamServer`]).
//!
//! Elasticity: the pool spawns `max_workers` threads once; CarbonScaler's
//! per-slot allocation selects how many are *active* for each step, so
//! scaling up/down is O(1) — the measured analogue of Kubernetes replica
//! scaling, and the substrate the Carbon Profiler measures real marginal
//! capacity curves on.

use crate::runtime::pjrt::{self, Engine, TransformerArtifact};
use crate::runtime::params::{mean_loss, synth_batch, ParamServer};
use anyhow::{anyhow, bail, Context, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

enum Cmd {
    /// Compute gradients at `step` with the given parameters.
    Step { params: Arc<Vec<f32>>, step: u64 },
    Stop,
}

struct Reply {
    #[allow(dead_code)]
    worker: usize,
    loss: f32,
    grads: Vec<f32>,
}

/// Leader handle to the elastic pool.
pub struct WorkerPool {
    art: TransformerArtifact,
    txs: Vec<Sender<Cmd>>,
    rx: Receiver<Result<Reply>>,
    handles: Vec<JoinHandle<()>>,
    seed: u64,
}

impl WorkerPool {
    /// Spawn `max_workers` threads, each compiling the artifact on its own
    /// PJRT client. Returns once every worker is ready (first failure
    /// aborts).
    pub fn spawn(art: &TransformerArtifact, max_workers: usize, seed: u64) -> Result<WorkerPool> {
        if max_workers == 0 {
            bail!("need at least one worker");
        }
        let (reply_tx, reply_rx) = channel::<Result<Reply>>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let mut txs = Vec::with_capacity(max_workers);
        let mut handles = Vec::with_capacity(max_workers);

        for w in 0..max_workers {
            let (tx, rx) = channel::<Cmd>();
            txs.push(tx);
            let art = art.clone();
            let reply_tx = reply_tx.clone();
            let ready_tx = ready_tx.clone();
            handles.push(std::thread::spawn(move || {
                worker_main(w, art, rx, reply_tx, ready_tx, seed);
            }));
        }
        for _ in 0..max_workers {
            ready_rx
                .recv()
                .context("worker startup channel closed")??;
        }
        Ok(WorkerPool {
            art: art.clone(),
            txs,
            rx: reply_rx,
            handles,
            seed,
        })
    }

    pub fn max_workers(&self) -> usize {
        self.txs.len()
    }

    pub fn artifact(&self) -> &TransformerArtifact {
        &self.art
    }

    /// Run one data-parallel step on workers `0..active`: broadcast
    /// params, gather `active` gradient shards, average + apply SGD.
    /// Returns the mean loss.
    pub fn step(&self, ps: &mut ParamServer, active: usize) -> Result<f32> {
        if active == 0 || active > self.txs.len() {
            bail!("active {} outside [1, {}]", active, self.txs.len());
        }
        let params = Arc::new(ps.params().to_vec());
        let step = ps.steps();
        for tx in &self.txs[..active] {
            tx.send(Cmd::Step {
                params: Arc::clone(&params),
                step,
            })
            .map_err(|_| anyhow!("worker channel closed"))?;
        }
        let mut losses = Vec::with_capacity(active);
        let mut grads = Vec::with_capacity(active);
        for _ in 0..active {
            let r = self.rx.recv().context("reply channel closed")??;
            losses.push(r.loss);
            grads.push(r.grads);
        }
        ps.apply(&grads);
        Ok(mean_loss(&losses))
    }

    /// Samples processed per step at `active` workers.
    pub fn samples_per_step(&self, active: usize) -> usize {
        active * self.art.batch
    }

    /// The seed used for shard generation (for reproducing batches).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Graceful shutdown.
    pub fn shutdown(mut self) {
        for tx in &self.txs {
            let _ = tx.send(Cmd::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(
    id: usize,
    art: TransformerArtifact,
    rx: Receiver<Cmd>,
    reply_tx: Sender<Result<Reply>>,
    ready_tx: Sender<Result<()>>,
    seed: u64,
) {
    let engine = match Engine::load(&art.file) {
        Ok(e) => {
            let _ = ready_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let b = art.batch as i64;
    let s = art.seq_len as i64;

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Stop => break,
            Cmd::Step { params, step } => {
                let result = (|| -> Result<Reply> {
                    let (x, y) =
                        synth_batch(art.vocab, art.batch, art.seq_len, id as u64, step, seed);
                    let inputs = vec![
                        pjrt::literal_f32(&params, &[params.len() as i64])?,
                        pjrt::literal_i32(&x, &[b, s])?,
                        pjrt::literal_i32(&y, &[b, s])?,
                    ];
                    let outs = engine.execute(&inputs)?;
                    if outs.len() != 2 {
                        bail!("expected (loss, grads), got {} outputs", outs.len());
                    }
                    let loss = pjrt::to_vec_f32(&outs[0])?[0];
                    let grads = pjrt::to_vec_f32(&outs[1])?;
                    Ok(Reply {
                        worker: id,
                        loss,
                        grads,
                    })
                })();
                if reply_tx.send(result).is_err() {
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pjrt::Manifest;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn pool_trains_tiny_model() {
        let Some(m) = manifest() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let art = m.transformer("tiny").unwrap();
        let pool = WorkerPool::spawn(art, 2, 42).unwrap();
        let mut ps = ParamServer::init_from_layout(art, 7);
        ps.lr = 0.5;

        let first = pool.step(&mut ps, 2).unwrap();
        let mut last = first;
        for _ in 0..15 {
            last = pool.step(&mut ps, 2).unwrap();
        }
        assert!(first.is_finite() && last.is_finite());
        assert!(
            last < first,
            "loss should decrease: first {first} last {last}"
        );
        pool.shutdown();
    }

    #[test]
    fn elastic_rescale_between_steps() {
        let Some(m) = manifest() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let art = m.transformer("tiny").unwrap();
        let pool = WorkerPool::spawn(art, 3, 1).unwrap();
        let mut ps = ParamServer::init_from_layout(art, 7);
        for k in [1usize, 3, 2, 1] {
            let loss = pool.step(&mut ps, k).unwrap();
            assert!(loss.is_finite(), "k={k}");
        }
        assert!(pool.step(&mut ps, 0).is_err());
        assert!(pool.step(&mut ps, 4).is_err());
        pool.shutdown();
    }
}
