//! Flat-parameter buffer operations: the rust side of the training loop.
//!
//! The L2 train step returns `(loss, grads: f32[P])`; the coordinator
//! averages gradients across elastic workers and applies SGD here — no
//! python, no optimizer state inside the compiled artifact, and the worker
//! count never appears in a compiled shape.

use crate::util::rng::Rng;

/// Model parameters plus the SGD learning rate.
#[derive(Debug, Clone)]
pub struct ParamServer {
    params: Vec<f32>,
    pub lr: f32,
    steps: u64,
}

impl ParamServer {
    pub fn new(params: Vec<f32>, lr: f32) -> Self {
        ParamServer {
            params,
            lr,
            steps: 0,
        }
    }

    /// GPT-2-like random init matching python/compile/model.py's scale,
    /// used when starting training fresh from rust (layout-compatible by
    /// construction: only element count matters for SGD).
    pub fn init_random(n_params: usize, seed: u64, scale: f32) -> Self {
        let mut rng = Rng::new(seed);
        let params = (0..n_params)
            .map(|_| (rng.normal() as f32) * scale)
            .collect();
        ParamServer::new(params, 0.1)
    }

    /// Layout-aware init mirroring python/compile/model.py's `init_params`:
    /// layernorm scales = 1, biases = 0, embeddings ~ 0.02·N(0,1), weight
    /// matrices ~ N(0,1)/sqrt(fan_in). Without this, scales initialised
    /// near zero make layernorm outputs vanish and training stalls.
    pub fn init_from_layout(art: &crate::runtime::pjrt::TransformerArtifact, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut params = vec![0.0f32; art.n_params];
        for (name, off, shape) in &art.param_layout {
            let size: usize = shape.iter().product::<usize>().max(1);
            let slice = &mut params[*off..*off + size];
            if name.ends_with("_scale") {
                slice.fill(1.0);
            } else if name.ends_with("_bias")
                || name.ends_with("_b")
                || name.ends_with("_b1")
                || name.ends_with("_b2")
            {
                slice.fill(0.0);
            } else if name.contains("embed") {
                for v in slice.iter_mut() {
                    *v = 0.02 * rng.normal() as f32;
                }
            } else {
                let fan_in = shape.first().copied().unwrap_or(1).max(1) as f32;
                let std = 1.0 / fan_in.sqrt();
                for v in slice.iter_mut() {
                    *v = std * rng.normal() as f32;
                }
            }
        }
        ParamServer::new(params, 0.1)
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Apply one SGD step with the mean of `grads` (one per worker).
    /// Panics if any gradient length mismatches.
    pub fn apply(&mut self, grads: &[Vec<f32>]) {
        assert!(!grads.is_empty(), "no gradients to apply");
        let n = self.params.len();
        for g in grads {
            assert_eq!(g.len(), n, "gradient length mismatch");
        }
        let inv_k = 1.0 / grads.len() as f32;
        // Averaging + update fused in one pass over P.
        for i in 0..n {
            let mut avg = 0.0f32;
            for g in grads {
                avg += g[i];
            }
            self.params[i] -= self.lr * avg * inv_k;
        }
        self.steps += 1;
    }

    /// L2 norm of the parameters (finite-ness / divergence checks).
    pub fn param_norm(&self) -> f64 {
        self.params
            .iter()
            .map(|&p| (p as f64) * (p as f64))
            .sum::<f64>()
            .sqrt()
    }
}

/// Mean of per-worker losses.
pub fn mean_loss(losses: &[f32]) -> f32 {
    if losses.is_empty() {
        return f32::NAN;
    }
    losses.iter().sum::<f32>() / losses.len() as f32
}

/// Deterministic synthetic token batch for worker `worker` at step `step`.
///
/// Sequences follow the affine chain `t_{i+1} = (a * t_i + b) mod vocab`
/// from a random start token: a fully learnable next-token distribution,
/// so the e2e loss curve demonstrably converges. `x` holds the sequence,
/// `y` the next tokens.
pub fn synth_batch(
    vocab: usize,
    batch: usize,
    seq_len: usize,
    worker: u64,
    step: u64,
    seed: u64,
) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(
        seed ^ worker.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ step.wrapping_mul(0x2545_F491_4F6C_DD1D),
    );
    let a = 5usize; // gcd(a, vocab) == 1 for power-of-two vocab
    let b = 7usize;
    let mut x = Vec::with_capacity(batch * seq_len);
    let mut y = Vec::with_capacity(batch * seq_len);
    for _ in 0..batch {
        let mut t = rng.below(vocab as u64) as usize;
        for _ in 0..seq_len {
            x.push(t as i32);
            t = (a * t + b) % vocab;
            y.push(t as i32);
        }
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_averages_gradients() {
        let mut ps = ParamServer::new(vec![1.0, 2.0], 0.5);
        ps.apply(&[vec![1.0, 0.0], vec![3.0, 0.0]]);
        // avg = [2, 0]; params -= 0.5 * avg = [0, 2].
        assert_eq!(ps.params(), &[0.0, 2.0]);
        assert_eq!(ps.steps(), 1);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn apply_checks_lengths() {
        let mut ps = ParamServer::new(vec![1.0], 0.1);
        ps.apply(&[vec![1.0, 2.0]]);
    }

    #[test]
    fn single_worker_equals_plain_sgd() {
        let mut a = ParamServer::new(vec![1.0, 1.0], 0.1);
        let mut b = ParamServer::new(vec![1.0, 1.0], 0.1);
        a.apply(&[vec![0.5, -0.5]]);
        b.apply(&[vec![0.5, -0.5], vec![0.5, -0.5]]); // identical grads
        assert_eq!(a.params(), b.params());
    }

    #[test]
    fn synth_batch_deterministic_and_learnable() {
        let (x1, y1) = synth_batch(64, 4, 16, 0, 0, 42);
        let (x2, y2) = synth_batch(64, 4, 16, 0, 0, 42);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        // Learnability: y is the affine image of x everywhere.
        for (xi, yi) in x1.iter().zip(&y1) {
            assert_eq!(*yi as usize, (5 * (*xi as usize) + 7) % 64);
        }
        // Different workers and steps draw different batches.
        let (x3, _) = synth_batch(64, 4, 16, 1, 0, 42);
        let (x4, _) = synth_batch(64, 4, 16, 0, 1, 42);
        assert_ne!(x1, x3);
        assert_ne!(x1, x4);
    }

    #[test]
    fn batch_values_in_vocab() {
        let (x, y) = synth_batch(512, 8, 64, 3, 9, 7);
        assert_eq!(x.len(), 8 * 64);
        assert!(x.iter().chain(&y).all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn mean_loss_math() {
        assert_eq!(mean_loss(&[1.0, 3.0]), 2.0);
        assert!(mean_loss(&[]).is_nan());
    }

    #[test]
    fn init_random_deterministic() {
        let a = ParamServer::init_random(100, 7, 0.02);
        let b = ParamServer::init_random(100, 7, 0.02);
        assert_eq!(a.params(), b.params());
        assert!(a.param_norm() > 0.0);
    }
}
