//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them.
//!
//! This is the only bridge between the rust coordinator and the L2/L1
//! compute graphs. Artifacts are HLO **text** (see python/compile/aot.py
//! for why not serialized protos); `HloModuleProto::from_text_file`
//! reassigns instruction ids and compiles cleanly on the CPU PJRT client.
//!
//! The xla crate's `PjRtClient` is `Rc`-backed (not `Send`), so each
//! worker thread constructs its own [`Engine`] — exactly the process
//! model of a real distributed worker owning its accelerator runtime.

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Manifest entry describing a transformer train-step artifact.
#[derive(Debug, Clone)]
pub struct TransformerArtifact {
    pub file: PathBuf,
    pub eval_file: PathBuf,
    pub n_params: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    /// Flat-parameter layout: (name, offset, shape) — the ABI contract
    /// with python/compile/model.py, used for layout-aware init.
    pub param_layout: Vec<(String, usize, Vec<usize>)>,
}

/// Manifest entry describing an N-body step artifact.
#[derive(Debug, Clone)]
pub struct NBodyArtifact {
    pub file: PathBuf,
    pub n_bodies: usize,
    pub softening: f64,
}

/// Parsed artifacts/manifest.json.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub transformers: Vec<(String, TransformerArtifact)>,
    pub nbodies: Vec<(String, NBodyArtifact)>,
}

impl Manifest {
    /// Load from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let mut m = Manifest::default();
        let arts = doc
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts object"))?;
        for (name, entry) in arts {
            let kind = entry.get("kind").and_then(Json::as_str).unwrap_or("");
            let file = |key: &str| -> Result<PathBuf> {
                Ok(dir.join(
                    entry
                        .get(key)
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: missing {key}"))?,
                ))
            };
            let num = |key: &str| -> Result<usize> {
                entry
                    .get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{name}: missing {key}"))
            };
            match kind {
                "transformer_train_step" => {
                    let mut layout = Vec::new();
                    if let Some(obj) = entry.get("param_layout").and_then(Json::as_obj) {
                        for (pname, meta) in obj {
                            let off = meta
                                .get("offset")
                                .and_then(Json::as_usize)
                                .ok_or_else(|| anyhow!("{pname}: missing offset"))?;
                            let shape: Vec<usize> = meta
                                .get("shape")
                                .and_then(Json::as_arr)
                                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                                .unwrap_or_default();
                            layout.push((pname.clone(), off, shape));
                        }
                        layout.sort_by_key(|(_, off, _)| *off);
                    }
                    m.transformers.push((
                        name.clone(),
                        TransformerArtifact {
                            file: file("file")?,
                            eval_file: file("eval_file")?,
                            n_params: num("n_params")?,
                            batch: num("batch")?,
                            seq_len: num("seq_len")?,
                            vocab: num("vocab")?,
                            d_model: num("d_model")?,
                            n_layers: num("n_layers")?,
                            param_layout: layout,
                        },
                    ));
                }
                "nbody_step" => {
                    m.nbodies.push((
                        name.clone(),
                        NBodyArtifact {
                            file: file("file")?,
                            n_bodies: num("n_bodies")?,
                            softening: entry
                                .get("softening")
                                .and_then(Json::as_f64)
                                .unwrap_or(0.05),
                        },
                    ));
                }
                other => bail!("unknown artifact kind {other:?}"),
            }
        }
        Ok(m)
    }

    pub fn transformer(&self, preset: &str) -> Option<&TransformerArtifact> {
        self.transformers
            .iter()
            .find(|(n, _)| n == &format!("transformer_{preset}"))
            .map(|(_, a)| a)
    }

    pub fn nbody(&self, preset: &str) -> Option<&NBodyArtifact> {
        self.nbodies
            .iter()
            .find(|(n, _)| n == &format!("nbody_{preset}"))
            .map(|(_, a)| a)
    }
}

/// A compiled executable bound to a thread-local PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

impl Engine {
    /// Load + compile an HLO text artifact on a fresh CPU client.
    pub fn load(hlo_path: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", hlo_path.display()))?;
        Ok(Engine { client, exe })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute with literal inputs; returns the flattened tuple outputs
    /// (aot.py lowers with return_tuple=True, so there is exactly one
    /// tuple result whose elements we unpack).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }
}

/// f32 vector -> literal of the given dimensions.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// i32 vector -> literal of the given dimensions.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Scalar f32 literal.
pub fn literal_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_parses() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.transformer("tiny").is_some());
        assert!(m.nbody("tiny").is_some());
        let t = m.transformer("tiny").unwrap();
        assert!(t.n_params > 0 && t.file.exists());
    }

    #[test]
    fn manifest_missing_dir_errors() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }
}
