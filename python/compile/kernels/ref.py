"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: pytest asserts the Pallas kernels
match these to tight tolerances across a hypothesis-driven shape/value
sweep (python/tests/test_kernels.py). They are also used by the L2 model
tests to cross-check the kernel-backed model against a kernel-free one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Reference matmul with f32 accumulation (matches kernels.matmul)."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(jnp.float32)


def nbody_forces_ref(pos: jax.Array, masses: jax.Array, softening: float) -> jax.Array:
    """Reference all-pairs gravitational accelerations.

    a_i = sum_j m_j * (p_j - p_i) / (|p_j - p_i|^2 + eps^2)^(3/2)

    The i == j term self-cancels because the displacement is zero and the
    softening keeps the denominator finite, matching the kernel exactly.

    Args:
      pos: (n, 3) positions.
      masses: (n,) masses.
      softening: Plummer softening length eps.

    Returns:
      (n, 3) accelerations.
    """
    # (n, n, 3) displacement tensor: d[i, j] = p[j] - p[i].
    disp = pos[None, :, :] - pos[:, None, :]
    dist2 = jnp.sum(disp * disp, axis=-1) + softening * softening
    inv_d3 = dist2 ** (-1.5)
    # weight[i, j] = m_j / (|d|^2 + eps^2)^(3/2)
    w = masses[None, :] * inv_d3
    return jnp.sum(w[:, :, None] * disp, axis=1)


def nbody_step_ref(
    pos: jax.Array,
    vel: jax.Array,
    masses: jax.Array,
    dt: float,
    softening: float,
) -> tuple[jax.Array, jax.Array]:
    """Reference leapfrog (kick-drift-kick) integration step."""
    acc = nbody_forces_ref(pos, masses, softening)
    vel_half = vel + 0.5 * dt * acc
    pos_new = pos + dt * vel_half
    acc_new = nbody_forces_ref(pos_new, masses, softening)
    vel_new = vel_half + 0.5 * dt * acc_new
    return pos_new, vel_new
