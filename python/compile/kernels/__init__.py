"""L1: Pallas kernels for the paper's compute hot-spots.

``matmul``  — tiled MXU-shaped matmul, used by every linear layer of the
              L2 transformer (the ML-training workload analog).
``nbody_forces`` / ``nbody_step`` — all-pairs gravity, the MPI N-body
              workload analog (Table 1).
``ref``     — pure-jnp oracles; the pytest ground truth.
"""

from .matmul import matmul, block_dims, vmem_bytes, mxu_utilization  # noqa: F401
from .nbody import nbody_forces, nbody_step  # noqa: F401
from . import ref  # noqa: F401
