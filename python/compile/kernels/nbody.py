"""L1 Pallas kernel: all-pairs N-body gravitational accelerations.

This is the compute hot-spot of the paper's MPI N-body workload (Table 1:
N=10,000 and N=100,000 configurations). The classical CUDA formulation
(GPU Gems 3, ch. 31) strides source bodies through shared memory per
threadblock; the TPU re-think per DESIGN.md §Hardware-Adaptation expresses
the same schedule with a 2-D Pallas grid:

* grid axis 0 tiles the *target* bodies (one (bt, 3) position block stays
  resident in VMEM with its (bt, 3) accumulator);
* grid axis 1 streams *source* tiles (bs bodies + masses) through VMEM —
  the BlockSpec plays the role of the CUDA shared-memory staging loop;
* the (bt, bs) interaction tile is evaluated on the VPU with an f32
  rsqrt-free formulation (dist2**-1.5) identical to the oracle in ref.py.

interpret=True on this image (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile edges: multiples of the 8x128 VPU register tile. A (256, 512)
# interaction tile uses 4 * (256*3 + 512*3 + 512 + 256*3) ~ 16 KB of VMEM,
# far under budget; bigger tiles only help once N is in the tens of
# thousands.
DEFAULT_BT = 256
DEFAULT_BS = 512


def _pick_tile(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (prefers multiples of 8)."""
    if n <= cap:
        return n
    best = 1
    for cand in range(cap, 0, -1):
        if n % cand == 0:
            if cand % 8 == 0:
                return cand
            if best == 1:
                best = cand
    return best


def _forces_kernel(pos_t_ref, pos_s_ref, mass_s_ref, acc_ref, *, n_s: int, softening: float):
    """Grid = (n/bt, n/bs); source axis (1) is innermost and sequential."""
    ss = pl.program_id(1)

    @pl.when(ss == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pt = pos_t_ref[...]  # (bt, 3) targets, VMEM-resident across the sweep
    ps = pos_s_ref[...]  # (bs, 3) streamed sources
    ms = mass_s_ref[...]  # (bs,)

    # (bt, bs, 3) displacement tile: d[i, j] = ps[j] - pt[i].
    disp = ps[None, :, :] - pt[:, None, :]
    dist2 = jnp.sum(disp * disp, axis=-1) + softening * softening
    w = ms[None, :] * dist2 ** (-1.5)  # (bt, bs)
    acc_ref[...] += jnp.sum(w[:, :, None] * disp, axis=1)


@functools.partial(jax.jit, static_argnames=("softening", "interpret"))
def nbody_forces(
    pos: jax.Array,
    masses: jax.Array,
    *,
    softening: float = 0.05,
    interpret: bool = True,
) -> jax.Array:
    """All-pairs accelerations via the tiled Pallas kernel.

    Args:
      pos: (n, 3) f32 positions.
      masses: (n,) f32 masses.
      softening: Plummer softening length (self-interaction cancels).
      interpret: keep True on CPU PJRT.

    Returns:
      (n, 3) f32 accelerations, matching ref.nbody_forces_ref.
    """
    n, three = pos.shape
    assert three == 3, f"pos must be (n, 3), got {pos.shape}"
    bt = _pick_tile(n, DEFAULT_BT)
    bs = _pick_tile(n, DEFAULT_BS)
    n_s = n // bs

    return pl.pallas_call(
        functools.partial(_forces_kernel, n_s=n_s, softening=softening),
        grid=(n // bt, n_s),
        in_specs=[
            pl.BlockSpec((bt, 3), lambda i, s: (i, 0)),
            pl.BlockSpec((bs, 3), lambda i, s: (s, 0)),
            pl.BlockSpec((bs,), lambda i, s: (s,)),
        ],
        out_specs=pl.BlockSpec((bt, 3), lambda i, s: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 3), jnp.float32),
        interpret=interpret,
    )(pos, pos, masses)


def nbody_step(
    pos: jax.Array,
    vel: jax.Array,
    masses: jax.Array,
    dt: float,
    *,
    softening: float = 0.05,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Leapfrog (kick-drift-kick) step built on the Pallas force kernel."""
    acc = nbody_forces(pos, masses, softening=softening, interpret=interpret)
    vel_half = vel + 0.5 * dt * acc
    pos_new = pos + dt * vel_half
    acc_new = nbody_forces(pos_new, masses, softening=softening, interpret=interpret)
    vel_new = vel_half + 0.5 * dt * acc_new
    return pos_new, vel_new
