"""L1 Pallas kernel: tiled matmul with f32 accumulation.

This is the compute hot-spot of the L2 transformer training step: every
linear layer (QKV projections, attention output, MLP) routes through
``matmul``.  The kernel is written for the TPU mental model per
DESIGN.md §Hardware-Adaptation:

* blocks are sized so that the working set (one x-block, one y-block, one
  output accumulator) stays within a ~16 MiB VMEM budget;
* block dims are multiples of the 128x128 MXU tile where the problem shape
  allows, so the systolic array would be fully utilised on real hardware;
* accumulation is f32, matching MXU semantics;
* the K dimension is the innermost, sequential grid axis: the output block
  stays resident in VMEM across the K sweep while x/y K-tiles are streamed
  through — the Pallas analog of a CUDA threadblock looping K-tiles in
  shared memory.

On this image Pallas runs under ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls), so the kernel lowers to plain HLO and
is checked against the pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget we tile for (bytes). Real TPUv4 has ~16 MiB per core; we keep
# headroom for double buffering of the streamed K-tiles.
VMEM_BUDGET = 12 * 1024 * 1024

# MXU systolic-array tile edge.
MXU_TILE = 128


def block_dims(m: int, n: int, k: int) -> tuple[int, int, int]:
    """Choose (bm, bn, bk) block dims for an (m, k) x (k, n) matmul.

    Preference order: MXU-aligned 128-multiples, then the full dim when it
    is already small. The VMEM constraint is
    ``4 * (bm*bk + bk*bn + bm*bn) <= VMEM_BUDGET`` with f32 operands.
    """

    def pick(dim: int, cap: int) -> int:
        if dim <= cap:
            return dim
        best = 1
        for cand in range(cap, 0, -1):
            if dim % cand == 0:
                if cand % MXU_TILE == 0:
                    return cand
                if best == 1:
                    best = cand
        return best

    bm = pick(m, 256)
    bn = pick(n, 256)
    bk = pick(k, 512)
    # Shrink bk until the f32 working set fits the VMEM budget.
    while 4 * (bm * bk + bk * bn + bm * bn) > VMEM_BUDGET and bk > 1:
        nbk = bk // 2
        while nbk > 1 and k % nbk != 0:
            nbk -= 1
        if nbk == bk:
            break
        bk = nbk
    return bm, bn, bk


def vmem_bytes(m: int, n: int, k: int) -> int:
    """f32 VMEM working-set estimate for the chosen blocking (for DESIGN.md)."""
    bm, bn, bk = block_dims(m, n, k)
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int) -> float:
    """Fraction of MXU lanes busy for the chosen blocking (estimate).

    An (bm, bk) x (bk, bn) block matmul keeps ``min(bm,128)/128 *
    min(bn,128)/128`` of the 128x128 systolic array busy per pass.
    """
    bm, bn, _ = block_dims(m, n, k)
    return min(bm, MXU_TILE) / MXU_TILE * min(bn, MXU_TILE) / MXU_TILE


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """Grid = (m/bm, n/bn, k/bk); K innermost and sequential.

    The (i, j) output block is revisited for every kk, so it acts as the
    VMEM-resident accumulator; it is zeroed on the first K step.
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _matmul_raw(x: jax.Array, y: jax.Array) -> jax.Array:
    """The pallas_call itself (no autodiff rules)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm, bn, bk = block_dims(m, n, k)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y)


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Tiled Pallas matmul: ``x @ y`` with f32 accumulation.

    Differentiable: the custom VJP routes both cotangent contractions
    (``g @ y.T`` and ``x.T @ g``) through the same Pallas kernel, so the
    backward pass stays on the kernel hot path.

    Args:
      x: (m, k) f32 array.
      y: (k, n) f32 array.

    Returns:
      (m, n) f32 array.
    """
    return _matmul_raw(x, y)


def _matmul_fwd(x, y):
    return _matmul_raw(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    return _matmul_raw(g, y.T), _matmul_raw(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
