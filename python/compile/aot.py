"""AOT-lower the L2 workload graphs to HLO text artifacts for the rust runtime.

HLO **text** (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  train_step_<preset>.hlo.txt   (params f32[P], x i32[B,S], y i32[B,S])
                                  -> (loss f32[], grads f32[P])
  eval_loss_<preset>.hlo.txt    (params, x, y) -> (loss,)
  nbody_step_<preset>.hlo.txt   (pos f32[N,3], vel f32[N,3], masses f32[N],
                                  dt f32[]) -> (pos', vel')
  manifest.json                 shape/offset metadata the rust loader reads

Run via ``make artifacts``; python never runs on the request path.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Presets lowered by default. `tiny` keeps rust integration tests fast;
# `small` is the train_e2e / serving artifact.
DEFAULT_TRANSFORMER_PRESETS = ("tiny", "small")
DEFAULT_NBODY_PRESETS = ("tiny", "small")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step(cfg: model.TransformerConfig) -> str:
    p = jax.ShapeDtypeStruct((cfg.n_params,), jnp.float32)
    x = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    y = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)

    def step(params, xb, yb):
        loss, grads = model.train_step(cfg, params, xb, yb, use_kernel=True)
        return loss, grads

    return to_hlo_text(jax.jit(step).lower(p, x, y))


def lower_eval_loss(cfg: model.TransformerConfig) -> str:
    p = jax.ShapeDtypeStruct((cfg.n_params,), jnp.float32)
    x = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    y = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)

    def ev(params, xb, yb):
        return (model.loss_fn(cfg, params, xb, yb, use_kernel=True),)

    return to_hlo_text(jax.jit(ev).lower(p, x, y))


def lower_nbody_step(cfg: model.NBodyConfig) -> str:
    pos = jax.ShapeDtypeStruct((cfg.n_bodies, 3), jnp.float32)
    vel = jax.ShapeDtypeStruct((cfg.n_bodies, 3), jnp.float32)
    masses = jax.ShapeDtypeStruct((cfg.n_bodies,), jnp.float32)
    dt = jax.ShapeDtypeStruct((), jnp.float32)

    def step(p, v, m, d):
        return model.nbody_step(cfg, p, v, m, d, use_kernel=True)

    return to_hlo_text(jax.jit(step).lower(pos, vel, masses, dt))


def transformer_manifest_entry(name: str, cfg: model.TransformerConfig) -> dict:
    offsets = {}
    off = 0
    for pname, shape in cfg.param_shapes():
        size = 1
        for s in shape:
            size *= s
        offsets[pname] = {"offset": off, "shape": list(shape)}
        off += size
    return {
        "kind": "transformer_train_step",
        "file": f"train_step_{name}.hlo.txt",
        "eval_file": f"eval_loss_{name}.hlo.txt",
        "n_params": cfg.n_params,
        "batch": cfg.batch,
        "seq_len": cfg.seq_len,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "param_layout": offsets,
    }


def nbody_manifest_entry(name: str, cfg: model.NBodyConfig) -> dict:
    return {
        "kind": "nbody_step",
        "file": f"nbody_step_{name}.hlo.txt",
        "n_bodies": cfg.n_bodies,
        "softening": cfg.softening,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=str(pathlib.Path(__file__).parents[2] / "artifacts"))
    ap.add_argument(
        "--transformer-presets", nargs="*", default=list(DEFAULT_TRANSFORMER_PRESETS)
    )
    ap.add_argument("--nbody-presets", nargs="*", default=list(DEFAULT_NBODY_PRESETS))
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    manifest: dict = {"format": "hlo-text", "artifacts": {}}

    for name in args.transformer_presets:
        cfg = model.PRESETS[name]
        hlo = lower_train_step(cfg)
        (out / f"train_step_{name}.hlo.txt").write_text(hlo)
        print(f"train_step_{name}: P={cfg.n_params} hlo={len(hlo)/1e6:.1f} MB")
        ev = lower_eval_loss(cfg)
        (out / f"eval_loss_{name}.hlo.txt").write_text(ev)
        manifest["artifacts"][f"transformer_{name}"] = transformer_manifest_entry(
            name, cfg
        )

    for name in args.nbody_presets:
        cfg = model.NBODY_PRESETS[name]
        hlo = lower_nbody_step(cfg)
        (out / f"nbody_step_{name}.hlo.txt").write_text(hlo)
        print(f"nbody_step_{name}: N={cfg.n_bodies} hlo={len(hlo)/1e6:.1f} MB")
        manifest["artifacts"][f"nbody_{name}"] = nbody_manifest_entry(name, cfg)

    (out / "manifest.json").write_text(json.dumps(manifest, indent=2, sort_keys=True))
    print(f"wrote {out}/manifest.json")


if __name__ == "__main__":
    main()
