"""L2: elastic-workload compute graphs in JAX, calling the L1 kernels.

Two workload graphs mirror the paper's Table 1 workload classes:

* a GPT-style transformer language model **training step** (the analog of
  the paper's PyTorch ResNet/VGG/EfficientNet training jobs), exposed with
  a *flat parameter vector* ABI::

      train_step(params f32[P], x i32[B,S], y i32[B,S]) -> (loss f32[], grads f32[P])

  so the rust coordinator can average gradients across an elastic number of
  workers and apply the SGD update with plain slice arithmetic — worker
  count changes at any slot boundary without recompilation;

* an **N-body leapfrog step** (the analog of the paper's MPI N-body job)::

      nbody_step(pos f32[N,3], vel f32[N,3], masses f32[N], dt f32[]) -> (pos', vel')

Every linear-layer matmul routes through the Pallas kernel
(`kernels.matmul`); attention score/context contractions are small batched
einsums left to XLA fusion (documented hot-path split, see DESIGN.md).
Both graphs are lowered once to HLO text by `aot.py` and never run in
python at request time.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import matmul
from .kernels import nbody as nbody_kernels
from .kernels import ref as kernel_ref


# ---------------------------------------------------------------------------
# Transformer configuration and flat-parameter layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    """Shape configuration for the transformer LM workload."""

    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 64
    batch: int = 8  # per-worker microbatch

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_shapes(self) -> list[tuple[str, tuple[int, ...]]]:
        """Ordered (name, shape) list defining the flat-parameter layout.

        The order here *is* the ABI: rust indexes the flat vector by these
        offsets (exported in artifacts/manifest.json).
        """
        shapes: list[tuple[str, tuple[int, ...]]] = [
            ("tok_embed", (self.vocab, self.d_model)),
            ("pos_embed", (self.seq_len, self.d_model)),
        ]
        for i in range(self.n_layers):
            p = f"layer{i}."
            shapes += [
                (p + "ln1_scale", (self.d_model,)),
                (p + "ln1_bias", (self.d_model,)),
                (p + "qkv_w", (self.d_model, 3 * self.d_model)),
                (p + "qkv_b", (3 * self.d_model,)),
                (p + "proj_w", (self.d_model, self.d_model)),
                (p + "proj_b", (self.d_model,)),
                (p + "ln2_scale", (self.d_model,)),
                (p + "ln2_bias", (self.d_model,)),
                (p + "mlp_w1", (self.d_model, self.d_ff)),
                (p + "mlp_b1", (self.d_ff,)),
                (p + "mlp_w2", (self.d_ff, self.d_model)),
                (p + "mlp_b2", (self.d_model,)),
            ]
        shapes += [
            ("lnf_scale", (self.d_model,)),
            ("lnf_bias", (self.d_model,)),
        ]
        return shapes

    @property
    def n_params(self) -> int:
        return sum(
            functools.reduce(lambda a, b: a * b, shape, 1)
            for _, shape in self.param_shapes()
        )


# Named presets; `small` is the train_e2e artifact, `tiny` keeps tests fast.
PRESETS: dict[str, TransformerConfig] = {
    "tiny": TransformerConfig(
        vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16, batch=4
    ),
    "small": TransformerConfig(),  # ~1.3M params
    "medium": TransformerConfig(
        vocab=1024, d_model=256, n_layers=6, n_heads=8, d_ff=1024, seq_len=128, batch=8
    ),
}


def unflatten(cfg: TransformerConfig, flat: jax.Array) -> dict[str, jax.Array]:
    """Slice the flat f32[P] vector into the named parameter dict."""
    params: dict[str, jax.Array] = {}
    off = 0
    for name, shape in cfg.param_shapes():
        size = functools.reduce(lambda a, b: a * b, shape, 1)
        params[name] = flat[off : off + size].reshape(shape)
        off += size
    assert off == flat.shape[0], f"flat param size {flat.shape[0]} != layout {off}"
    return params


def flatten(cfg: TransformerConfig, params: dict[str, jax.Array]) -> jax.Array:
    """Inverse of `unflatten` (used by tests and init)."""
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in cfg.param_shapes()]
    )


def init_params(cfg: TransformerConfig, key: jax.Array) -> jax.Array:
    """GPT-2-style initialization, returned flat."""
    params = {}
    for name, shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        if name.endswith(("_scale",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_bias", "_b", "_b1", "_b2", "qkv_b", "proj_b")):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif "embed" in name:
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(
                jnp.float32(fan_in)
            )
    return flatten(cfg, params)


# ---------------------------------------------------------------------------
# Transformer forward / loss
# ---------------------------------------------------------------------------


def _layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def _linear(
    x: jax.Array, w: jax.Array, b: jax.Array, mm: Callable[[jax.Array, jax.Array], jax.Array]
) -> jax.Array:
    """(B, S, Din) @ (Din, Dout) + b through the 2-D matmul hot path."""
    bsz, seq, din = x.shape
    out = mm(x.reshape(bsz * seq, din), w)
    return out.reshape(bsz, seq, w.shape[1]) + b


def forward(
    cfg: TransformerConfig,
    flat_params: jax.Array,
    x: jax.Array,
    *,
    use_kernel: bool = True,
) -> jax.Array:
    """Causal LM forward pass -> logits (B, S, V).

    `use_kernel=False` swaps every matmul for the pure-jnp oracle — the
    kernel-vs-reference parity check at the *model* level.
    """
    mm = (lambda a, b: matmul(a, b)) if use_kernel else kernel_ref.matmul_ref
    p = unflatten(cfg, flat_params)
    bsz, seq = x.shape

    h = p["tok_embed"][x] + p["pos_embed"][None, :seq, :]
    mask = jnp.tril(jnp.ones((seq, seq), jnp.float32))
    neg = jnp.finfo(jnp.float32).min

    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        a = _layer_norm(h, p[pre + "ln1_scale"], p[pre + "ln1_bias"])
        qkv = _linear(a, p[pre + "qkv_w"], p[pre + "qkv_b"], mm)
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(bsz, seq, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
            jnp.float32(cfg.head_dim)
        )
        scores = jnp.where(mask[None, None, :, :] > 0, scores, neg)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(bsz, seq, cfg.d_model)
        h = h + _linear(ctx, p[pre + "proj_w"], p[pre + "proj_b"], mm)

        b2 = _layer_norm(h, p[pre + "ln2_scale"], p[pre + "ln2_bias"])
        ff = _linear(b2, p[pre + "mlp_w1"], p[pre + "mlp_b1"], mm)
        ff = jax.nn.gelu(ff)
        h = h + _linear(ff, p[pre + "mlp_w2"], p[pre + "mlp_b2"], mm)

    h = _layer_norm(h, p["lnf_scale"], p["lnf_bias"])
    # Tied output projection: logits = h @ tok_embed.T
    logits = mm(
        h.reshape(bsz * seq, cfg.d_model), p["tok_embed"].T
    ).reshape(bsz, seq, cfg.vocab)
    return logits


def loss_fn(
    cfg: TransformerConfig,
    flat_params: jax.Array,
    x: jax.Array,
    y: jax.Array,
    *,
    use_kernel: bool = True,
) -> jax.Array:
    """Mean next-token cross-entropy."""
    logits = forward(cfg, flat_params, x, use_kernel=use_kernel)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_step(
    cfg: TransformerConfig,
    flat_params: jax.Array,
    x: jax.Array,
    y: jax.Array,
    *,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """The AOT'd unit of work: (loss, flat gradient).

    The SGD update and cross-worker gradient averaging happen in rust
    (`runtime::params`), keeping the artifact free of optimizer state and
    the worker count out of the compiled shape.
    """
    loss, grads = jax.value_and_grad(
        lambda fp: loss_fn(cfg, fp, x, y, use_kernel=use_kernel)
    )(flat_params)
    return loss, grads


def sgd_update(flat_params: jax.Array, grads: jax.Array, lr: float) -> jax.Array:
    """Reference SGD update (rust reimplements this; tests assert parity)."""
    return flat_params - lr * grads


# ---------------------------------------------------------------------------
# N-body workload graph
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NBodyConfig:
    """Shape configuration for the N-body workload."""

    n_bodies: int = 1024
    softening: float = 0.05


NBODY_PRESETS: dict[str, NBodyConfig] = {
    "tiny": NBodyConfig(n_bodies=128),
    "small": NBodyConfig(n_bodies=1024),
    "large": NBodyConfig(n_bodies=4096),
}


def nbody_step(
    cfg: NBodyConfig,
    pos: jax.Array,
    vel: jax.Array,
    masses: jax.Array,
    dt: jax.Array,
    *,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One leapfrog step; the AOT'd unit of work for the MPI-analog job."""
    if use_kernel:
        forces = lambda p: nbody_kernels.nbody_forces(
            p, masses, softening=cfg.softening
        )
    else:
        forces = lambda p: kernel_ref.nbody_forces_ref(p, masses, cfg.softening)
    acc = forces(pos)
    vel_half = vel + 0.5 * dt * acc
    pos_new = pos + dt * vel_half
    acc_new = forces(pos_new)
    vel_new = vel_half + 0.5 * dt * acc_new
    return pos_new, vel_new


def init_nbody(cfg: NBodyConfig, key: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Plummer-ish random initial conditions (positions, velocities, masses)."""
    k1, k2, k3 = jax.random.split(key, 3)
    pos = jax.random.normal(k1, (cfg.n_bodies, 3), jnp.float32)
    vel = 0.1 * jax.random.normal(k2, (cfg.n_bodies, 3), jnp.float32)
    masses = (
        jnp.abs(jax.random.normal(k3, (cfg.n_bodies,), jnp.float32)) + 0.5
    ) / cfg.n_bodies
    return pos, vel, masses
