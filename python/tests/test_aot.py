"""AOT artifact tests: HLO text round-trips and numerics match jax execution.

The round-trip here goes python -> HLO text -> xla_client compile -> execute,
which is exactly what the rust runtime does via the xla crate; any numeric
drift would show up identically on the rust side.
"""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model

ARTIFACTS = pathlib.Path(__file__).parents[2] / "artifacts"


def parse_hlo_text(text: str):
    """Round-trip the text through the HLO parser, as the rust loader does.

    jaxlib 0.8 no longer exposes direct execution of parsed HLO from python;
    execution parity with the artifacts is covered by the rust integration
    tests (rust/tests/runtime_roundtrip.rs), which exercise the actual
    xla-crate loader that serves the request path.
    """
    return xc._xla.hlo_module_from_text(text)


class TestLowering:
    def test_train_step_lowers(self):
        cfg = model.PRESETS["tiny"]
        text = aot.lower_train_step(cfg)
        assert "ENTRY" in text and "HloModule" in text

    def test_nbody_lowers(self):
        cfg = model.NBODY_PRESETS["tiny"]
        text = aot.lower_nbody_step(cfg)
        assert "ENTRY" in text

    def test_no_custom_calls(self):
        """interpret=True must lower pallas to plain HLO (no Mosaic custom-call)."""
        text = aot.lower_train_step(model.PRESETS["tiny"])
        assert "tpu_custom_call" not in text
        text = aot.lower_nbody_step(model.NBODY_PRESETS["tiny"])
        assert "tpu_custom_call" not in text


class TestManifest:
    def test_manifest_exists_and_consistent(self):
        mpath = ARTIFACTS / "manifest.json"
        if not mpath.exists():
            pytest.skip("run `make artifacts` first")
        manifest = json.loads(mpath.read_text())
        assert manifest["format"] == "hlo-text"
        for name, entry in manifest["artifacts"].items():
            assert (ARTIFACTS / entry["file"]).exists(), entry["file"]
            if entry["kind"] == "transformer_train_step":
                cfg = model.PRESETS[name.split("_", 1)[1]]
                assert entry["n_params"] == cfg.n_params
                # Layout offsets are contiguous and exhaustive.
                offs = sorted(
                    (v["offset"], v["shape"]) for v in entry["param_layout"].values()
                )
                total = 0
                for off, shape in offs:
                    assert off == total
                    sz = 1
                    for s in shape:
                        sz *= s
                    total += sz
                assert total == cfg.n_params


class TestRoundTrip:
    """HLO text parses back and declares the expected entry signature."""

    def test_train_step_parses_with_signature(self):
        cfg = model.PRESETS["tiny"]
        text = aot.lower_train_step(cfg)
        mod = parse_hlo_text(text)
        sig = mod.to_string()
        # 3 entry parameters with the flat-param ABI shapes.
        assert f"f32[{cfg.n_params}]" in sig
        assert f"s32[{cfg.batch},{cfg.seq_len}]" in sig

    def test_nbody_parses_with_signature(self):
        cfg = model.NBODY_PRESETS["tiny"]
        text = aot.lower_nbody_step(cfg)
        mod = parse_hlo_text(text)
        sig = mod.to_string()
        assert f"f32[{cfg.n_bodies},3]" in sig

    def test_text_roundtrip_stable(self):
        """Parsing and re-printing must be idempotent (ids reassigned once)."""
        text = aot.lower_nbody_step(model.NBODY_PRESETS["tiny"])
        once = parse_hlo_text(text).to_string()
        twice = parse_hlo_text(once).to_string()
        assert once == twice

    def test_artifacts_on_disk_parse(self):
        if not ARTIFACTS.exists():
            pytest.skip("run `make artifacts` first")
        for path in sorted(ARTIFACTS.glob("*.hlo.txt")):
            mod = parse_hlo_text(path.read_text())
            assert "ENTRY" in mod.to_string(), path.name

    def test_jax_execution_kernel_vs_ref_after_lowering(self):
        """The lowered (kernel-backed) graph equals the reference numerics.

        This executes the same jitted function that was lowered to the
        artifact, i.e. identical HLO modulo metadata, and compares against
        the kernel-free reference path.
        """
        cfg = model.PRESETS["tiny"]
        fp = model.init_params(cfg, jax.random.PRNGKey(0))
        kx, ky = jax.random.split(jax.random.PRNGKey(1))
        x = jax.random.randint(kx, (cfg.batch, cfg.seq_len), 0, cfg.vocab)
        y = jax.random.randint(ky, (cfg.batch, cfg.seq_len), 0, cfg.vocab)

        step = jax.jit(lambda p, a, b: model.train_step(cfg, p, a, b, use_kernel=True))
        loss_k, grads_k = step(fp, x, y)
        loss_r, grads_r = model.train_step(cfg, fp, x, y, use_kernel=False)
        np.testing.assert_allclose(loss_k, loss_r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(grads_k, grads_r, rtol=5e-3, atol=5e-4)
