"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and value distributions; every property failure is
a real numeric divergence between the kernel and `ref.py`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    block_dims,
    matmul,
    mxu_utilization,
    nbody_forces,
    nbody_step,
    ref,
    vmem_bytes,
)
from compile.kernels.matmul import MXU_TILE, VMEM_BUDGET


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


class TestMatmulBasic:
    def test_identity(self):
        x = rand(0, (32, 32))
        np.testing.assert_allclose(
            matmul(x, jnp.eye(32)), x, rtol=1e-5, atol=1e-5
        )

    def test_zeros(self):
        x = rand(0, (16, 24))
        out = matmul(x, jnp.zeros((24, 8), jnp.float32))
        assert not np.any(np.asarray(out))

    def test_small_square(self):
        x, y = rand(1, (8, 8)), rand(2, (8, 8))
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5
        )

    def test_rectangular(self):
        x, y = rand(1, (64, 96)), rand(2, (96, 48))
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5
        )

    def test_mxu_aligned(self):
        x, y = rand(1, (256, 512)), rand(2, (512, 384))
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
        )

    def test_vector_like(self):
        # m=1 degenerate case (single row).
        x, y = rand(1, (1, 64)), rand(2, (64, 32))
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5
        )

    def test_prime_dims(self):
        # Dims with no nice divisors exercise the fallback blocking.
        x, y = rand(1, (17, 23)), rand(2, (23, 31))
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5
        )

    def test_large_values(self):
        x, y = rand(1, (32, 32), 1e3), rand(2, (32, 32), 1e3)
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-1
        )

    def test_contraction_mismatch_raises(self):
        with pytest.raises(AssertionError):
            matmul(rand(1, (8, 9)), rand(2, (8, 9)))


class TestMatmulGrad:
    def test_vjp_matches_reference(self):
        x, y = rand(1, (32, 48)), rand(2, (48, 16))

        def f_kernel(x, y):
            return jnp.sum(matmul(x, y) ** 2)

        def f_ref(x, y):
            return jnp.sum(ref.matmul_ref(x, y) ** 2)

        gx_k, gy_k = jax.grad(f_kernel, argnums=(0, 1))(x, y)
        gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
        np.testing.assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gy_k, gy_r, rtol=1e-4, atol=1e-4)

    def test_grad_through_chain(self):
        x = rand(1, (16, 16))
        w1, w2 = rand(2, (16, 32)), rand(3, (32, 8))

        def f(w1, w2):
            return jnp.sum(jnp.tanh(matmul(jnp.tanh(matmul(x, w1)), w2)))

        def f_ref(w1, w2):
            h = jnp.tanh(ref.matmul_ref(x, w1))
            return jnp.sum(jnp.tanh(ref.matmul_ref(h, w2)))

        g1, g2 = jax.grad(f, argnums=(0, 1))(w1, w2)
        r1, r2 = jax.grad(f_ref, argnums=(0, 1))(w1, w2)
        np.testing.assert_allclose(g1, r1, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(g2, r2, rtol=1e-4, atol=1e-5)


class TestBlocking:
    """Structural invariants of the TPU-shaped blocking (DESIGN.md §HA)."""

    def test_blocks_divide_dims(self):
        for m, n, k in [(64, 64, 64), (256, 384, 512), (17, 23, 31), (1, 128, 256)]:
            bm, bn, bk = block_dims(m, n, k)
            assert m % bm == 0 and n % bn == 0 and k % bk == 0

    def test_vmem_budget_respected(self):
        for m, n, k in [(1024, 1024, 1024), (4096, 4096, 4096), (512, 65536, 128)]:
            assert vmem_bytes(m, n, k) <= VMEM_BUDGET

    def test_mxu_alignment_preferred(self):
        bm, bn, bk = block_dims(1024, 1024, 1024)
        assert bm % MXU_TILE == 0 and bn % MXU_TILE == 0

    def test_mxu_utilization_full_when_aligned(self):
        assert mxu_utilization(512, 512, 512) == 1.0

    def test_mxu_utilization_partial_small(self):
        assert mxu_utilization(32, 32, 32) == (32 / 128) ** 2


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    k=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
)
def test_matmul_property(m, n, k, seed, scale):
    """Kernel == oracle across arbitrary shapes and magnitudes."""
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = scale * jax.random.normal(kx, (m, k), jnp.float32)
    y = scale * jax.random.normal(ky, (k, n), jnp.float32)
    got = matmul(x, y)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * scale * k)


# ---------------------------------------------------------------------------
# nbody
# ---------------------------------------------------------------------------


class TestNBodyBasic:
    def test_two_body_symmetry(self):
        # Equal masses on the x axis: forces are equal and opposite.
        pos = jnp.array([[-1.0, 0, 0], [1.0, 0, 0]], jnp.float32)
        masses = jnp.array([1.0, 1.0], jnp.float32)
        acc = np.asarray(nbody_forces(pos, masses, softening=0.1))
        np.testing.assert_allclose(acc[0], -acc[1], rtol=1e-6)
        assert acc[0][0] > 0  # attraction toward the other body

    def test_matches_ref_small(self):
        pos = rand(0, (64, 3))
        masses = jnp.abs(rand(1, (64,))) + 0.1
        np.testing.assert_allclose(
            nbody_forces(pos, masses, softening=0.05),
            ref.nbody_forces_ref(pos, masses, 0.05),
            rtol=2e-4,
            atol=2e-4,
        )

    def test_matches_ref_non_tile_multiple(self):
        pos = rand(0, (300, 3))
        masses = jnp.abs(rand(1, (300,))) + 0.1
        np.testing.assert_allclose(
            nbody_forces(pos, masses, softening=0.05),
            ref.nbody_forces_ref(pos, masses, 0.05),
            rtol=2e-4,
            atol=2e-4,
        )

    def test_matches_ref_large(self):
        pos = rand(0, (1024, 3))
        masses = jnp.abs(rand(1, (1024,))) + 0.1
        np.testing.assert_allclose(
            nbody_forces(pos, masses, softening=0.05),
            ref.nbody_forces_ref(pos, masses, 0.05),
            rtol=5e-4,
            atol=5e-4,
        )

    def test_massless_sources_no_force(self):
        pos = rand(0, (32, 3))
        acc = nbody_forces(pos, jnp.zeros((32,), jnp.float32), softening=0.05)
        assert not np.any(np.asarray(acc))

    def test_step_matches_ref(self):
        pos, vel = rand(0, (128, 3)), 0.1 * rand(1, (128, 3))
        masses = jnp.abs(rand(2, (128,))) + 0.1
        p_k, v_k = nbody_step(pos, vel, masses, 0.01, softening=0.05)
        p_r, v_r = ref.nbody_step_ref(pos, vel, masses, 0.01, 0.05)
        np.testing.assert_allclose(p_k, p_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(v_k, v_r, rtol=1e-4, atol=1e-4)

    def test_momentum_conservation(self):
        # Total momentum change over one step ~ 0 for equal-softening forces.
        pos, vel = rand(0, (64, 3)), 0.1 * rand(1, (64, 3))
        masses = jnp.abs(rand(2, (64,))) + 0.5
        _, v1 = nbody_step(pos, vel, masses, 0.01, softening=0.1)
        p0 = np.asarray(jnp.sum(masses[:, None] * vel, axis=0))
        p1 = np.asarray(jnp.sum(masses[:, None] * v1, axis=0))
        np.testing.assert_allclose(p0, p1, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(4, 200),
    seed=st.integers(0, 2**31 - 1),
    softening=st.sampled_from([0.01, 0.05, 0.5]),
)
def test_nbody_property(n, seed, softening):
    """Kernel == oracle across body counts (incl. non-multiples of tiles)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    pos = jax.random.normal(k1, (n, 3), jnp.float32)
    masses = jnp.abs(jax.random.normal(k2, (n,), jnp.float32)) + 0.1
    got = nbody_forces(pos, masses, softening=softening)
    want = ref.nbody_forces_ref(pos, masses, softening)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
