"""L2 model tests: shapes, kernel-vs-reference parity, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

CFG = model.PRESETS["tiny"]


def make_batch(cfg, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(kx, (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    y = jax.random.randint(ky, (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    return x, y


class TestParamLayout:
    def test_n_params_matches_layout(self):
        total = 0
        for _, shape in CFG.param_shapes():
            sz = 1
            for s in shape:
                sz *= s
            total += sz
        assert total == CFG.n_params

    def test_flatten_unflatten_roundtrip(self):
        fp = model.init_params(CFG, jax.random.PRNGKey(0))
        back = model.flatten(CFG, model.unflatten(CFG, fp))
        np.testing.assert_array_equal(fp, back)

    def test_unflatten_rejects_wrong_size(self):
        with pytest.raises(AssertionError):
            model.unflatten(CFG, jnp.zeros((CFG.n_params + 1,), jnp.float32))

    def test_layout_deterministic(self):
        assert CFG.param_shapes() == CFG.param_shapes()

    def test_presets_have_distinct_sizes(self):
        sizes = {name: cfg.n_params for name, cfg in model.PRESETS.items()}
        assert sizes["tiny"] < sizes["small"] < sizes["medium"]


class TestForward:
    def test_logits_shape(self):
        fp = model.init_params(CFG, jax.random.PRNGKey(0))
        x, _ = make_batch(CFG)
        logits = model.forward(CFG, fp, x)
        assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)

    def test_kernel_matches_reference_forward(self):
        fp = model.init_params(CFG, jax.random.PRNGKey(0))
        x, _ = make_batch(CFG)
        lk = model.forward(CFG, fp, x, use_kernel=True)
        lr = model.forward(CFG, fp, x, use_kernel=False)
        np.testing.assert_allclose(lk, lr, rtol=1e-4, atol=1e-4)

    def test_causality(self):
        """Changing a future token must not change past logits."""
        fp = model.init_params(CFG, jax.random.PRNGKey(0))
        x, _ = make_batch(CFG)
        x2 = x.at[:, -1].set((x[:, -1] + 1) % CFG.vocab)
        l1 = model.forward(CFG, fp, x, use_kernel=False)
        l2 = model.forward(CFG, fp, x2, use_kernel=False)
        np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5, atol=1e-5)

    def test_loss_near_uniform_at_init(self):
        """Near-zero init -> loss ~ log(vocab)."""
        fp = model.init_params(CFG, jax.random.PRNGKey(0))
        x, y = make_batch(CFG)
        loss = float(model.loss_fn(CFG, fp, x, y, use_kernel=False))
        assert abs(loss - np.log(CFG.vocab)) < 0.5


class TestTrainStep:
    def test_grad_shapes(self):
        fp = model.init_params(CFG, jax.random.PRNGKey(0))
        x, y = make_batch(CFG)
        loss, grads = model.train_step(CFG, fp, x, y, use_kernel=False)
        assert loss.shape == ()
        assert grads.shape == (CFG.n_params,)

    def test_kernel_matches_reference_grads(self):
        fp = model.init_params(CFG, jax.random.PRNGKey(0))
        x, y = make_batch(CFG)
        lk, gk = model.train_step(CFG, fp, x, y, use_kernel=True)
        lr, gr = model.train_step(CFG, fp, x, y, use_kernel=False)
        np.testing.assert_allclose(lk, lr, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gk, gr, rtol=5e-3, atol=5e-4)

    def test_loss_decreases_under_sgd(self):
        """A few SGD steps on a fixed batch must reduce the loss."""
        fp = model.init_params(CFG, jax.random.PRNGKey(0))
        x, y = make_batch(CFG)
        losses = []
        for _ in range(5):
            loss, grads = model.train_step(CFG, fp, x, y, use_kernel=False)
            losses.append(float(loss))
            fp = model.sgd_update(fp, grads, 0.1)
        assert losses[-1] < losses[0]

    def test_grad_averaging_equals_big_batch(self):
        """Averaging per-shard grads == grad of the mean loss over shards.

        This is the exact contract the rust elastic worker pool relies on:
        k workers each compute grads on their own microbatch; the
        coordinator's average must equal a single large-batch gradient.
        """
        fp = model.init_params(CFG, jax.random.PRNGKey(0))
        x1, y1 = make_batch(CFG, seed=1)
        x2, y2 = make_batch(CFG, seed=2)
        _, g1 = model.train_step(CFG, fp, x1, y1, use_kernel=False)
        _, g2 = model.train_step(CFG, fp, x2, y2, use_kernel=False)
        avg = (g1 + g2) / 2

        xb = jnp.concatenate([x1, x2], axis=0)
        yb = jnp.concatenate([y1, y2], axis=0)
        big_cfg = model.TransformerConfig(
            **{
                **CFG.__dict__,
                "batch": CFG.batch * 2,
            }
        )
        _, gb = model.train_step(big_cfg, fp, xb, yb, use_kernel=False)
        np.testing.assert_allclose(avg, gb, rtol=1e-4, atol=1e-5)


class TestNBodyModel:
    def test_step_shapes(self):
        cfg = model.NBODY_PRESETS["tiny"]
        pos, vel, masses = model.init_nbody(cfg, jax.random.PRNGKey(0))
        dt = jnp.float32(0.01)
        p, v = model.nbody_step(cfg, pos, vel, masses, dt)
        assert p.shape == (cfg.n_bodies, 3) and v.shape == (cfg.n_bodies, 3)

    def test_kernel_matches_reference(self):
        cfg = model.NBODY_PRESETS["tiny"]
        pos, vel, masses = model.init_nbody(cfg, jax.random.PRNGKey(0))
        dt = jnp.float32(0.01)
        pk, vk = model.nbody_step(cfg, pos, vel, masses, dt, use_kernel=True)
        pr, vr = model.nbody_step(cfg, pos, vel, masses, dt, use_kernel=False)
        np.testing.assert_allclose(pk, pr, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(vk, vr, rtol=1e-4, atol=1e-4)

    def test_energy_roughly_conserved(self):
        """Leapfrog on a soft potential: KE+PE drift stays small over 20 steps."""
        cfg = model.NBodyConfig(n_bodies=64, softening=0.2)
        pos, vel, masses = model.init_nbody(cfg, jax.random.PRNGKey(0))

        def energy(pos, vel):
            ke = 0.5 * jnp.sum(masses * jnp.sum(vel * vel, axis=-1))
            disp = pos[None, :, :] - pos[:, None, :]
            dist = jnp.sqrt(jnp.sum(disp**2, axis=-1) + cfg.softening**2)
            pe = -0.5 * jnp.sum(masses[:, None] * masses[None, :] / dist)
            return float(ke + pe)

        e0 = energy(pos, vel)
        dt = jnp.float32(0.005)
        for _ in range(20):
            pos, vel = model.nbody_step(cfg, pos, vel, masses, dt, use_kernel=False)
        e1 = energy(pos, vel)
        assert abs(e1 - e0) / max(abs(e0), 1e-6) < 0.05


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_train_step_grads_finite(seed):
    """Gradients stay finite for any random init/batch."""
    fp = model.init_params(CFG, jax.random.PRNGKey(seed))
    x, y = make_batch(CFG, seed=seed)
    loss, grads = model.train_step(CFG, fp, x, y, use_kernel=False)
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(grads)))
