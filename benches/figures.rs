//! Figure-regeneration benchmarks: time each experiment in quick mode —
//! these are the end-to-end "one bench per paper table/figure" targets.

use carbonscaler::expt::{self, ExpContext};
use carbonscaler::util::bench::bench;
use std::time::Duration;

fn main() {
    let ctx = ExpContext { seed: 2023, quick: true };
    for e in expt::all() {
        let id = e.id();
        bench(&format!("expt {id} (quick)"), 0, 1, Duration::from_millis(1), || {
            e.run(&ctx).unwrap()
        });
    }
}
