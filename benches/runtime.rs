//! PJRT runtime benchmarks: per-step execution cost and elastic pool
//! scaling (the real marginal-capacity measurement). Requires
//! `make artifacts`.

use carbonscaler::runtime::{Manifest, ParamServer, WorkerPool};
use carbonscaler::util::bench::bench;
use std::path::PathBuf;
use std::time::Duration;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(manifest) = Manifest::load(&dir) else {
        eprintln!("skipping runtime bench: run `make artifacts` first");
        return;
    };

    for preset in ["tiny", "small"] {
        let Some(art) = manifest.transformer(preset) else { continue };
        let max = 4usize;
        let pool = WorkerPool::spawn(art, max, 1).expect("pool");
        println!("== {preset} (P={}) ==", art.n_params);
        let budget = Duration::from_secs(2);
        let mut base = None;
        for k in 1..=max {
            let mut ps = ParamServer::init_from_layout(art, 7);
            let r = bench(
                &format!("train step k={k} ({}sm/step)", k * art.batch),
                2,
                5,
                budget,
                || pool.step(&mut ps, k).unwrap(),
            );
            let thr = (k * art.batch) as f64 / r.mean.as_secs_f64();
            if k == 1 {
                base = Some(thr);
            }
            println!(
                "    -> {:.0} samples/s (scaling efficiency {:.2})",
                thr,
                thr / base.unwrap() / k as f64
            );
        }
        pool.shutdown();
    }

    // N-body step timing.
    for preset in ["tiny", "small"] {
        let Some(art) = manifest.nbody(preset) else { continue };
        let mut sim = carbonscaler::runtime::nbody::NBodySim::new(art, 1).expect("sim");
        bench(
            &format!("nbody step N={}", art.n_bodies),
            2,
            5,
            Duration::from_secs(1),
            || sim.step(0.01).unwrap(),
        );
    }
}
