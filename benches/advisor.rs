//! Carbon Advisor benchmarks: simulator throughput and full start-time
//! sweeps (the figure-harness workhorse).

use carbonscaler::advisor::{self, SimConfig};
use carbonscaler::carbon::{regions, synthetic};
use carbonscaler::sched::{CarbonAgnostic, CarbonScalerPolicy};
use carbonscaler::util::bench::bench;
use carbonscaler::workload::catalog;
use std::time::Duration;

fn main() {
    let trace = synthetic::generate(regions::by_name("ontario").unwrap(), 60 * 24, 1);
    let w = catalog::by_name("resnet18").unwrap();
    let job = w.job(0, 24.0, 1.5, 8).unwrap();
    let cfg = SimConfig::default();
    let budget = Duration::from_millis(500);

    println!("== single simulation ==");
    bench("simulate carbonscaler 24h job", 3, 20, budget, || {
        advisor::simulate(&CarbonScalerPolicy, &job, &trace, &cfg).unwrap()
    });
    bench("simulate carbon-agnostic 24h job", 3, 20, budget, || {
        advisor::simulate(&CarbonAgnostic, &job, &trace, &cfg).unwrap()
    });
    bench("simulate w/ 30% forecast error", 3, 20, budget, || {
        advisor::simulate(
            &CarbonScalerPolicy,
            &job,
            &trace,
            &SimConfig { forecast_error: 0.3, ..Default::default() },
        )
        .unwrap()
    });

    println!("\n== sweeps ==");
    let starts = advisor::even_starts(trace.len(), 48, 40);
    bench("40-start sweep (fig-harness unit)", 1, 3, Duration::from_secs(2), || {
        advisor::sweep_start_times(&CarbonScalerPolicy, &job, &trace, &starts, &cfg).unwrap()
    });
}
