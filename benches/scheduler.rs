//! Scheduler benchmarks: Algorithm 1 (and the polish pass) across the
//! paper-relevant (n slots, M servers) space. Target (DESIGN.md §7):
//! paper scale n=96, M=64 well under 1 ms for the raw greedy.

use carbonscaler::carbon::{regions, synthetic};
use carbonscaler::scaling::models::presets;
use carbonscaler::sched::greedy;
use carbonscaler::util::bench::bench;
use carbonscaler::workload::JobBuilder;
use std::time::Duration;

fn main() {
    let trace = synthetic::generate(regions::by_name("ontario").unwrap(), 120 * 24, 1);
    let budget = Duration::from_millis(400);

    println!("== Algorithm 1 (raw greedy) ==");
    for (n_hours, m_servers) in [(24usize, 8usize), (96, 8), (96, 64), (336, 64), (96, 256)] {
        let curve = presets::RESNET18.curve(m_servers);
        let job = JobBuilder::new("bench", curve)
            .servers(1, m_servers)
            .length(n_hours as f64 / 1.5)
            .slack_factor(1.5)
            .build()
            .unwrap();
        let carbon = trace.window(0, job.n_slots());
        bench(
            &format!("greedy n={n_hours} M={m_servers}"),
            3,
            20,
            budget,
            || greedy::plan(&job, &carbon).unwrap(),
        );
    }

    println!("\n== Algorithm 1 + polish (production policy) ==");
    for (n_hours, m_servers) in [(24usize, 8usize), (96, 8), (96, 64)] {
        let curve = presets::RESNET18.curve(m_servers);
        let job = JobBuilder::new("bench", curve)
            .servers(1, m_servers)
            .length(n_hours as f64 / 1.5)
            .slack_factor(1.5)
            .build()
            .unwrap();
        let carbon = trace.window(0, job.n_slots());
        bench(
            &format!("polished n={n_hours} M={m_servers}"),
            2,
            10,
            budget,
            || greedy::plan_polished(&job, &carbon).unwrap(),
        );
    }

    println!("\n== recomputation (plan_remaining, mid-execution) ==");
    let curve = presets::RESNET18.curve(8);
    let job = JobBuilder::new("bench", curve)
        .length(64.0)
        .slack_factor(1.5)
        .build()
        .unwrap();
    let carbon = trace.window(48, 48);
    bench("plan_remaining n=48 M=8", 3, 20, budget, || {
        greedy::plan_remaining(&job, &carbon, 48, 32.0, 0.5).unwrap()
    });
}
