//! Scheduler benchmarks: Algorithm 1 (and the polish pass) across the
//! paper-relevant (n slots, M servers) space, plus the fleet engine at
//! multi-tenant scale. Targets (DESIGN.md §7): paper scale n=96, M=64
//! well under 1 ms for the raw greedy; 100 jobs x 96 slots under 50 ms
//! for a full fleet plan. Results are also written to
//! `BENCH_scheduler.json` so future changes have a perf trajectory.

use carbonscaler::advisor::{self, SimConfig};
use carbonscaler::carbon::{regions, synthetic};
use carbonscaler::expt::interactive::{job_mix, services, truths, REGION_CAPACITY};
use carbonscaler::scaling::models::presets;
use carbonscaler::sched::dirty::{DirtySet, SlotIndex};
use carbonscaler::sched::engine;
use carbonscaler::sched::fleet::{self, PlanContext};
use carbonscaler::sched::geo::{self, GeoPlanContext, MigrationPolicy};
use carbonscaler::sched::greedy;
use carbonscaler::sched::interactive;
use carbonscaler::sched::reference;
use carbonscaler::service::api::{self, ServiceState};
use carbonscaler::service::http::HttpServer;
use carbonscaler::service::loadgen::{JobTemplate, LoadGen};
use carbonscaler::service::shard::{ShardPool, ShardPoolConfig};
use carbonscaler::util::bench::{bench, BenchResult};
use carbonscaler::util::json::Json;
use carbonscaler::workload::interactive::ServiceSpec;
use carbonscaler::workload::{JobBuilder, JobSpec};
use std::time::Duration;

fn main() {
    let trace = synthetic::generate(regions::by_name("ontario").unwrap(), 120 * 24, 1);
    let budget = Duration::from_millis(400);
    let mut results: Vec<BenchResult> = Vec::new();

    println!("== Algorithm 1 (raw greedy) ==");
    for (n_hours, m_servers) in [(24usize, 8usize), (96, 8), (96, 64), (336, 64), (96, 256)] {
        let curve = presets::RESNET18.curve(m_servers);
        let job = JobBuilder::new("bench", curve)
            .servers(1, m_servers)
            .length(n_hours as f64 / 1.5)
            .slack_factor(1.5)
            .build()
            .unwrap();
        let carbon = trace.window(0, job.n_slots());
        results.push(bench(
            &format!("greedy n={n_hours} M={m_servers}"),
            3,
            20,
            budget,
            || greedy::plan(&job, &carbon).unwrap(),
        ));
    }

    println!("\n== Algorithm 1 + polish (production policy) ==");
    for (n_hours, m_servers) in [(24usize, 8usize), (96, 8), (96, 64)] {
        let curve = presets::RESNET18.curve(m_servers);
        let job = JobBuilder::new("bench", curve)
            .servers(1, m_servers)
            .length(n_hours as f64 / 1.5)
            .slack_factor(1.5)
            .build()
            .unwrap();
        let carbon = trace.window(0, job.n_slots());
        results.push(bench(
            &format!("polished n={n_hours} M={m_servers}"),
            2,
            10,
            budget,
            || greedy::plan_polished(&job, &carbon).unwrap(),
        ));
    }

    println!("\n== recomputation (plan_remaining, mid-execution) ==");
    let curve = presets::RESNET18.curve(8);
    let job = JobBuilder::new("bench", curve)
        .length(64.0)
        .slack_factor(1.5)
        .build()
        .unwrap();
    let carbon = trace.window(48, 48);
    results.push(bench("plan_remaining n=48 M=8", 3, 20, budget, || {
        greedy::plan_remaining(&job, &carbon, 48, 32.0, 0.5).unwrap()
    }));

    println!("\n== fleet engine (multi-job, capacity-capped, 96-slot windows) ==");
    for (n_jobs, cap) in [(50usize, 96usize), (100, 128), (200, 256)] {
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| {
                JobBuilder::new(&format!("j{i}"), presets::RESNET18.curve(8))
                    .servers(1, 8)
                    .arrival(i % 24)
                    .length(64.0)
                    .slack_factor(1.5)
                    .build()
                    .unwrap()
            })
            .collect();
        let end = jobs.iter().map(|j| j.deadline()).max().unwrap();
        let ctx = PlanContext::uniform(0, cap, trace.window(0, end)).unwrap();
        results.push(bench(
            &format!("fleet greedy jobs={n_jobs} n=96 cap={cap}"),
            2,
            10,
            budget,
            || fleet::plan_fleet_greedy(&jobs, &ctx).expect("bench fleet feasible"),
        ));
        if n_jobs == 100 {
            // The acceptance bar: a full production plan (greedy +
            // sequential portfolio) for 100 jobs x 96 slots.
            results.push(bench(
                &format!("fleet plan jobs={n_jobs} n=96 cap={cap}"),
                2,
                10,
                budget,
                || fleet::plan_fleet(&jobs, &ctx).expect("bench fleet feasible"),
            ));
        }
    }

    println!("\n== hot-path overhaul (flat arena + bucket queue vs retained reference) ==");
    {
        // ISSUE 6 acceptance: the flat-arena/bucketed-queue planner must
        // be >= 5x faster than the retained pre-overhaul implementation
        // (sched::reference — Vec<Vec<_>> state + BinaryHeap) on the
        // 100 jobs x 96 slots acceptance case, and a cold 1k-job plan
        // must be sub-second. Both are gated in CI
        // (.github/scripts/bench_gate.py "ratio_gates" + the 1k entry in
        // BENCH_baseline.json "gated").
        let mk_jobs = |n_jobs: usize| -> Vec<JobSpec> {
            (0..n_jobs)
                .map(|i| {
                    JobBuilder::new(&format!("s{i}"), presets::RESNET18.curve(8))
                        .servers(1, 8)
                        .arrival(i % 24)
                        .length(64.0)
                        .slack_factor(1.5)
                        .build()
                        .unwrap()
                })
                .collect()
        };
        {
            let jobs = mk_jobs(100);
            let end = jobs.iter().map(|j| j.deadline()).max().unwrap();
            let ctx = PlanContext::uniform(0, 128, trace.window(0, end)).unwrap();
            results.push(bench(
                "fleet plan reference jobs=100 n=96 cap=128",
                2,
                10,
                budget,
                || reference::plan_fleet(&jobs, &ctx).expect("bench reference feasible"),
            ));
        }
        // 10k-job scale: the 1k -> 10k mean-time ratio is gated <= 15x,
        // i.e. the planner must scale no worse than ~n^1.18 across that
        // decade (candidate count grows linearly; the bucket queue keeps
        // the per-pop cost from compounding).
        let scale_budget = Duration::from_secs(20);
        let mut scale_means: Vec<(usize, f64)> = Vec::new();
        for n_jobs in [1000usize, 10_000] {
            let jobs = mk_jobs(n_jobs);
            let end = jobs.iter().map(|j| j.deadline()).max().unwrap();
            let cap = n_jobs * 128 / 100; // same per-job contention as 100@128
            let ctx = PlanContext::uniform(0, cap, trace.window(0, end)).unwrap();
            let iters = if n_jobs >= 10_000 { 2 } else { 3 };
            let r = bench(
                &format!("fleet plan jobs={n_jobs} n=96 cap={cap}"),
                1,
                iters,
                scale_budget,
                || fleet::plan_fleet(&jobs, &ctx).expect("bench fleet feasible"),
            );
            scale_means.push((n_jobs, r.mean.as_nanos() as f64));
            results.push(r);
        }
        let scaling = scale_means[1].1 / scale_means[0].1.max(1.0);
        println!("fleet plan 1k -> 10k scaling: {scaling:.1}x (acceptance: <= 15x)");
    }

    println!("\n== online engine (warm-start repair vs cold replan, DESIGN.md §10) ==");
    {
        // ISSUE 4 acceptance: warm-start repair after ONE arrival at fleet
        // scale (100 jobs x 96-slot windows) must be >= 5x faster than a
        // cold plan_fleet recompute. The ratio is gated in CI
        // (.github/scripts/bench_gate.py, "ratio_gates").
        let (n_jobs, cap) = (100usize, 128usize);
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| {
                JobBuilder::new(&format!("o{i}"), presets::RESNET18.curve(8))
                    .servers(1, 8)
                    .arrival(i % 24)
                    .length(64.0)
                    .slack_factor(1.5)
                    .build()
                    .unwrap()
            })
            .collect();
        let end = jobs.iter().map(|j| j.deadline()).max().unwrap();
        let ctx = PlanContext::uniform(0, cap, trace.window(0, end)).unwrap();
        let incumbent_jobs = &jobs[..n_jobs - 1];
        let incumbent =
            fleet::plan_fleet(incumbent_jobs, &ctx).expect("bench incumbent feasible");
        let newcomer = &jobs[n_jobs - 1];
        let cold = bench(
            &format!("engine cold replan jobs={n_jobs} n=96 cap={cap}"),
            2,
            10,
            budget,
            || fleet::plan_fleet(&jobs, &ctx).expect("bench cold feasible"),
        );
        let warm = bench(
            &format!("engine warm repair 1 arrival jobs={n_jobs} n=96 cap={cap}"),
            2,
            10,
            budget,
            || {
                engine::repair_arrival(incumbent_jobs, &incumbent, newcomer, &ctx, 0)
                    .expect("bench warm repair feasible")
            },
        );
        let speedup = cold.mean.as_nanos() as f64 / warm.mean.as_nanos().max(1) as f64;
        println!("warm-start repair speedup vs cold replan: {speedup:.1}x (acceptance: >= 5x)");
        results.push(cold);
        results.push(warm);
    }

    println!("\n== dirty-slot revision repair (incremental vs full warm, DESIGN.md §13) ==");
    {
        // ISSUE 7 acceptance: a forecast revision dirtying <= 10% of the
        // horizon must repair >= 5x faster through the dirty-slot path
        // (`repair_fleet_revision`) than through the full warm-repair
        // portfolio re-opening the same touched set, and an empty-diff
        // re-issue must be >= 20x faster. Both ratios are gated in CI
        // (bench_gate.py "ratio_gates") on the 1k-job instance; the 10%
        // and 50% rows chart how the advantage decays as the touched set
        // grows — at 50% the fallback ladder routes to the full
        // portfolio itself, so the ratio collapses to ~1x by design.
        //
        // Jobs here have short (9-slot) windows spread over a ~100-slot
        // horizon: revisions with local effect are the regime the dirty
        // path exists for. Fleets of horizon-spanning jobs degenerate to
        // touched == everyone, which the ladder hands to the full
        // portfolio anyway.
        let mk_short = |n_jobs: usize| -> Vec<JobSpec> {
            (0..n_jobs)
                .map(|i| {
                    JobBuilder::new(&format!("d{i}"), presets::RESNET18.curve(8))
                        .servers(1, 8)
                        .arrival(i % 96)
                        .length(6.0)
                        .slack_factor(1.5)
                        .build()
                        .unwrap()
                })
                .collect()
        };
        let touched_of = |incumbent: &fleet::FleetSchedule, dirty: &DirtySet, ctx: &PlanContext| {
            SlotIndex::build(ctx.horizon(), |f| {
                for (ji, s) in incumbent.schedules.iter().enumerate() {
                    for (rel, &a) in s.alloc.iter().enumerate() {
                        if a == 0 {
                            continue;
                        }
                        if let Some(fi) = ctx.rel(s.arrival + rel) {
                            f(fi, ji as u32, a as u32);
                        }
                    }
                }
            })
            .jobs_on(dirty)
        };
        for n_jobs in [1000usize, 10_000] {
            let jobs = mk_short(n_jobs);
            let end = jobs.iter().map(|j| j.deadline()).max().unwrap();
            let cap = n_jobs * 128 / 1000; // same per-job contention at both scales
            let ctx = PlanContext::uniform(0, cap, trace.window(0, end)).unwrap();
            let incumbent = fleet::plan_fleet(&jobs, &ctx).expect("bench incumbent feasible");
            let h = ctx.horizon();
            let (warmup, iters, case_budget) = if n_jobs >= 10_000 {
                (1, 3, Duration::from_secs(10))
            } else {
                (2, 10, budget)
            };
            for pct in [1usize, 10, 50] {
                let lo = h / 3;
                let w = (h * pct / 100).max(1).min(h - lo);
                let mut carbon = ctx.carbon.clone();
                for c in &mut carbon[lo..lo + w] {
                    *c *= 1.5;
                }
                let dirty = DirtySet::from_carbon_diff(&ctx.carbon, &carbon[lo..lo + w], lo, 0);
                let ctx2 = PlanContext::uniform(0, cap, carbon).unwrap();
                let touched = touched_of(&incumbent, &dirty, &ctx2);
                let dirty_r = bench(
                    &format!("dirty revision repair jobs={n_jobs} dirty={pct}%"),
                    warmup,
                    iters,
                    case_budget,
                    || {
                        engine::repair_fleet_revision(
                            &jobs,
                            &incumbent.schedules,
                            &dirty,
                            &ctx2,
                            0,
                        )
                        .expect("bench dirty repair feasible")
                    },
                );
                let full_r = bench(
                    &format!("full warm revision repair jobs={n_jobs} dirty={pct}%"),
                    warmup,
                    iters,
                    case_budget,
                    || {
                        engine::repair_fleet(
                            &jobs,
                            &incumbent.schedules,
                            &touched,
                            &[],
                            &ctx2,
                            0,
                            true,
                        )
                        .expect("bench full warm repair feasible")
                    },
                );
                let speedup =
                    full_r.mean.as_nanos() as f64 / dirty_r.mean.as_nanos().max(1) as f64;
                println!(
                    "dirty repair speedup at {pct}% dirty ({} touched of {n_jobs}): \
                     {speedup:.1}x",
                    touched.len()
                );
                results.push(dirty_r);
                results.push(full_r);
            }
            // Empty-diff re-issue: the dirty path answers from the diff
            // alone (incumbent passthrough, zero seeding).
            let empty = DirtySet::new(h);
            let noop_r = bench(
                &format!("noop revision repair jobs={n_jobs}"),
                warmup,
                iters,
                case_budget,
                || {
                    engine::repair_fleet_revision(&jobs, &incumbent.schedules, &empty, &ctx, 0)
                        .expect("bench noop repair feasible")
                },
            );
            let full_noop_r = bench(
                &format!("full warm noop revision jobs={n_jobs}"),
                warmup,
                iters,
                case_budget,
                || {
                    engine::repair_fleet(&jobs, &incumbent.schedules, &[], &[], &ctx, 0, true)
                        .expect("bench full noop repair feasible")
                },
            );
            let speedup =
                full_noop_r.mean.as_nanos() as f64 / noop_r.mean.as_nanos().max(1) as f64;
            println!("no-op revision speedup: {speedup:.1}x (acceptance: >= 20x at 1k)");
            results.push(noop_r);
            results.push(full_noop_r);
        }
    }

    println!("\n== service layer (pallas-serve sharded submit throughput, DESIGN.md §11) ==");
    {
        // ISSUE 5 acceptance: the sharded server must sustain >= 2x the
        // single-shard submit throughput at 4 shards. Each iteration
        // stands up a fresh service on an ephemeral loopback port and
        // pushes a fixed batch of jobs through the real HTTP + loadgen
        // path; the wall time per batch is the inverse throughput, so
        // the CI ratio gate (bench_gate.py "ratio_gates") asserts
        // 1-shard mean >= 2x the 4-shard mean, machine-independently.
        const N_JOBS: usize = 720;
        const THREADS: usize = 8;
        const CLUSTER: usize = 768;
        const HORIZON: usize = 96;
        let carbon = trace.window(0, HORIZON);
        let service_budget = Duration::from_secs(3);
        for shards in [1usize, 4] {
            let carbon = carbon.clone();
            results.push(bench(
                &format!("service submit jobs={N_JOBS} shards={shards}"),
                1,
                3,
                service_budget,
                || {
                    let pool = ShardPool::start(ShardPoolConfig::new(
                        shards,
                        CLUSTER,
                        carbon.clone(),
                    ))
                    .expect("bench pool starts");
                    let state = ServiceState::new(pool);
                    let server =
                        HttpServer::bind("127.0.0.1:0", THREADS, api::handler(state.clone()))
                            .expect("bench server binds");
                    let template = JobTemplate {
                        length_hours: 48.0,
                        slack: 1.8,
                        max_servers: 8,
                        tenants: 96,
                        seed: 7,
                    };
                    let report = LoadGen::new(server.addr(), THREADS, template)
                        .saturation(N_JOBS)
                        .expect("bench loadgen runs");
                    assert_eq!(report.errors, 0, "service bench must be error-free");
                    assert_eq!(
                        report.admitted, N_JOBS,
                        "service bench must admit every job (load is ~52%)"
                    );
                    server.shutdown();
                    state.pool().shutdown();
                    report.admitted
                },
            ));
        }
        let single = &results[results.len() - 2];
        let sharded = &results[results.len() - 1];
        let speedup =
            single.mean.as_nanos() as f64 / sharded.mean.as_nanos().max(1) as f64;
        println!(
            "sharded submit throughput speedup 4 vs 1 shards: {speedup:.1}x (acceptance: >= 2x)"
        );
    }

    println!("\n== durability (WAL fsync ingest vs snapshot-free replay, DESIGN.md §14) ==");
    {
        // ISSUE 8 acceptance: startup replay of a 1k-event WAL must be
        // >= 20x faster than the original durable ingest of those same
        // events. Ingest pays one fsync per batch (sequential submits =>
        // one-event batches, the worst case); replay re-drives the same
        // events through the unchanged engine commit path with zero
        // fsyncs and no reply plumbing. The ratio is gated in CI
        // (bench_gate.py "ratio_gates") so a regression that starts
        // fsyncing on the replay path, or batching on the ingest path
        // without logging, fails loudly.
        const EVENTS: usize = 1000;
        const CLUSTER: usize = 512;
        const HORIZON: usize = 96;
        let carbon = trace.window(0, HORIZON);
        let dir = std::env::temp_dir().join(format!("pallas-bench-wal-{}", std::process::id()));
        let mk_job = |i: usize| {
            JobBuilder::new(&format!("w{i}"), presets::RESNET18.curve(4))
                .servers(1, 4)
                .arrival(i % 90)
                .length(4.0)
                .slack_factor(1.5)
                .build()
                .unwrap()
        };
        let ingest = bench(
            &format!("wal ingest events={EVENTS}"),
            1,
            3,
            Duration::from_secs(2),
            || {
                let _ = std::fs::remove_dir_all(&dir);
                let pool = ShardPool::start(
                    ShardPoolConfig::new(1, CLUSTER, carbon.clone())
                        .durable(&dir)
                        .compact_every(1_000_000),
                )
                .expect("bench durable pool starts");
                for i in 0..EVENTS {
                    pool.submit(&format!("t{}", i % 16), "resnet18", mk_job(i))
                        .expect("bench submit succeeds");
                }
                // Kill (not shutdown): leave the WAL exactly as a crash
                // would, for the replay bench to recover from.
                pool.kill();
            },
        );
        let replay = bench(
            &format!("wal replay events={EVENTS}"),
            1,
            5,
            Duration::from_secs(2),
            || {
                let pool = ShardPool::start(
                    ShardPoolConfig::new(1, CLUSTER, carbon.clone())
                        .durable(&dir)
                        .compact_every(1_000_000),
                )
                .expect("bench recovery starts");
                let snap = pool.snapshots().remove(0);
                assert_eq!(
                    snap.replayed_events, EVENTS,
                    "replay bench must re-drive the full log"
                );
                pool.kill();
                snap.replayed_events
            },
        );
        let speedup = ingest.mean.as_nanos() as f64 / replay.mean.as_nanos().max(1) as f64;
        println!("wal replay speedup vs durable ingest: {speedup:.1}x (acceptance: >= 20x)");
        let _ = std::fs::remove_dir_all(&dir);
        results.push(ingest);
        results.push(replay);
    }

    println!("\n== group commit (durable ingest under concurrent submitters, DESIGN.md §14) ==");
    {
        // ISSUE 9 acceptance: with >= 8 concurrent submitters, the
        // pipelined group commit must ingest >= 3x faster than the
        // legacy per-batch-fsync ordering (planning thread blocks on
        // fsync before every reply). The comparison is deliberately
        // rigged against amortization-by-accident: max_batch is pinned
        // to 1 so admission batching cannot merge submits into one
        // record batch — every event is its own planning batch, and in
        // per-batch mode therefore its own fsync. In group mode the
        // writer coalesces whatever accumulated during the previous
        // sync, so up to THREADS closed-loop submitters share each
        // fsync. mode=none (no WAL) charts the planning-only ceiling.
        // The 1k group/per-batch ratio is gated in CI (bench_gate.py
        // "ratio_gates").
        const THREADS: usize = 8;
        const CLUSTER: usize = 64;
        const HORIZON: usize = 24;
        let carbon = trace.window(0, HORIZON);
        let dir = std::env::temp_dir().join(format!("pallas-bench-gc-{}", std::process::id()));
        fn gc_job(t: usize, k: usize) -> JobSpec {
            JobBuilder::new(&format!("gc-{t}-{k}"), presets::RESNET18.curve(2))
                .servers(1, 2)
                .length(1.0)
                .slack_factor(3.0)
                .build()
                .unwrap()
        }
        // Closed-loop drive: each submitter completes its previous job
        // after the next submit, so the active set stays O(THREADS) and
        // planning cost is flat — the durability path is what's timed.
        let drive = |pool: &ShardPool, events: usize| {
            let per_thread = events / THREADS;
            std::thread::scope(|scope| {
                for t in 0..THREADS {
                    scope.spawn(move || {
                        let mut prev: Option<String> = None;
                        for k in 0..per_thread {
                            let out = pool
                                .submit(&format!("tenant-{t}"), "resnet18", gc_job(t, k))
                                .expect("bench submit succeeds");
                            assert!(
                                matches!(out, carbonscaler::service::shard::SubmitResult::Admitted(_)),
                                "bench must admit every job"
                            );
                            if let Some(p) = prev.take() {
                                let _ = pool.complete(&p);
                            }
                            prev = Some(format!("gc-{t}-{k}"));
                        }
                    });
                }
            });
        };
        for events in [1000usize, 10_000] {
            let (warmup, iters, case_budget) = if events >= 10_000 {
                (0, 1, Duration::from_secs(30))
            } else {
                (1, 2, Duration::from_secs(4))
            };
            for mode in ["per-batch", "group", "none"] {
                let carbon = carbon.clone();
                let dir = dir.clone();
                results.push(bench(
                    &format!("wal ingest mode={mode} events={events}"),
                    warmup,
                    iters,
                    case_budget,
                    || {
                        let _ = std::fs::remove_dir_all(&dir);
                        let mut cfg = ShardPoolConfig::new(1, CLUSTER, carbon.clone());
                        cfg.max_batch = 1;
                        let cfg = match mode {
                            "per-batch" => cfg.durable(&dir).per_batch_fsync(),
                            "group" => cfg.durable(&dir),
                            _ => cfg,
                        };
                        let pool = ShardPool::start(cfg).expect("bench pool starts");
                        drive(&pool, events);
                        pool.kill();
                    },
                ));
            }
            let per_batch = &results[results.len() - 3];
            let group = &results[results.len() - 2];
            let speedup =
                per_batch.mean.as_nanos() as f64 / group.mean.as_nanos().max(1) as f64;
            println!(
                "group-commit ingest speedup vs per-batch fsync at {events} events, \
                 {THREADS} submitters: {speedup:.1}x (acceptance: >= 3x at 1k)"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    println!("\n== geo engine (multi-region placement, 96-slot windows) ==");
    {
        let (n_jobs, n_regions, cap) = (40usize, 8usize, 16usize);
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| {
                JobBuilder::new(&format!("g{i}"), presets::RESNET18.curve(8))
                    .servers(1, 8)
                    .arrival(i % 24)
                    .length(64.0)
                    .slack_factor(1.5)
                    .build()
                    .unwrap()
            })
            .collect();
        let end = jobs.iter().map(|j| j.deadline()).max().unwrap();
        let geo_ctx = GeoPlanContext::synthetic(
            &regions::REGIONS[..n_regions],
            0,
            end,
            cap,
            1,
            MigrationPolicy::none(),
        )
        .unwrap();
        results.push(bench(
            &format!("geo plan jobs={n_jobs} regions={n_regions} cap={cap}"),
            1,
            5,
            budget,
            || geo::plan_geo(&jobs, &geo_ctx).expect("bench geo feasible"),
        ));
    }

    println!("\n== interactive co-scheduling (SLO routing + capacity squeeze, DESIGN.md §15) ==");
    {
        // ISSUE 10 acceptance, two parts.
        //
        // (1) Timing: the exact per-slot transportation solve at catalog
        //     scale — every region (37), a 96-slot window, 12 streams
        //     with 60 ms floors wide enough to reach much of the
        //     catalog. Budget (DESIGN.md §15): well under 150 ms per
        //     route() call; informational in the baseline because the
        //     absolute cost is runner-shaped, while the carbon gate
        //     below is machine-independent.
        const HORIZON: usize = 96;
        let geo_all = GeoPlanContext::synthetic(
            regions::REGIONS,
            0,
            HORIZON,
            16,
            1,
            MigrationPolicy::none(),
        )
        .unwrap();
        let specs: Vec<ServiceSpec> = (0..12)
            .map(|i| ServiceSpec {
                name: format!("svc-{i}"),
                home: regions::REGIONS[(i * 3) % regions::REGIONS.len()].name.to_string(),
                slo_ms: 60.0,
                peak_servers: 6,
                arrival: 0,
                hours: HORIZON,
                power_watts: 210.0,
            })
            .collect();
        let set = interactive::build_set(&specs, &geo_all, 1).unwrap();
        results.push(bench(
            &format!(
                "interactive route regions={} slots={HORIZON} streams={}",
                regions::REGIONS.len(),
                specs.len()
            ),
            2,
            10,
            budget,
            || {
                let plan = interactive::route(&set, &geo_all);
                assert!(plan.respects_capacity(&geo_all));
                plan.served
            },
        ));

        // (2) Machine-independent carbon gate: on the expt bench
        //     instance (3 streams homed in the dirty half of the region
        //     slice + the 5-job batch mix), the co-scheduled joint
        //     carbon must not exceed route-to-nearest's at equal
        //     service. Both totals are recorded as pseudo-durations
        //     (1 g => 1 µs) so the CI ratio gate (bench_gate.py
        //     "ratio_gates", min_ratio 1.0 with nearest as "slow")
        //     compares them with the same machinery as the timing
        //     gates — the unit cancels in the ratio, so the gate holds
        //     on any machine.
        // Seed 2023 matches ExpContext::default(), i.e. the exact
        // instance expt::interactive's unit tests prove violation-free
        // and batch-complete for both policies.
        let jobs = job_mix().expect("bench job mix builds");
        let tr = truths(2023);
        let cfg = SimConfig::default();
        let streams = services(60.0);
        let co = advisor::simulate_joint(
            &jobs, &streams, &tr, REGION_CAPACITY, MigrationPolicy::none(), &cfg,
        )
        .expect("bench co-sched sim feasible");
        let near = advisor::simulate_joint_nearest(
            &jobs, &streams, &tr, REGION_CAPACITY, MigrationPolicy::none(), &cfg,
        )
        .expect("bench nearest sim feasible");
        // The comparison is only meaningful at equal service: both
        // policies must serve every request-slot and finish the batch.
        assert_eq!(co.slo_violations, 0, "co-sched bench must serve everything");
        assert_eq!(near.slo_violations, 0, "nearest bench must serve everything");
        assert_eq!(co.interactive_served, near.interactive_served);
        assert!(co.batch.all_finished() && near.batch.all_finished());
        let grams_case = |label: &str, grams: f64| {
            let d = Duration::from_nanos((grams * 1000.0).round().max(1.0) as u64);
            let r = BenchResult {
                name: label.to_string(),
                iters: 1,
                mean: d,
                p50: d,
                p99: d,
            };
            println!("{}", r.report());
            r
        };
        println!(
            "joint carbon: co-sched {:.0} g vs nearest {:.0} g (gate: co-sched <= nearest)",
            co.total_carbon_g(),
            near.total_carbon_g()
        );
        results.push(grams_case("interactive joint carbon nearest (1g=1us)", near.total_carbon_g()));
        results.push(grams_case("interactive joint carbon co-sched (1g=1us)", co.total_carbon_g()));
    }

    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj()
                .set("name", r.name.as_str())
                .set("iters", r.iters)
                .set("mean_ns", r.mean.as_nanos() as f64)
                .set("p50_ns", r.p50.as_nanos() as f64)
                .set("p99_ns", r.p99.as_nanos() as f64)
        })
        .collect();
    let doc = Json::obj()
        .set("bench", "scheduler")
        .set("results", Json::Arr(rows));
    // Cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the output at the workspace root so local runs and the CI
    // bench gate agree on the location.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_scheduler.json");
    match std::fs::write(&out, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", out.display()),
    }
}
