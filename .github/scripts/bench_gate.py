#!/usr/bin/env python3
"""Benchmark regression gate for the CI bench job.

Compares BENCH_scheduler.json (fresh run) against BENCH_baseline.json
(committed). Cases whose name is listed in the baseline's "gated" array
fail the build when mean_ns regresses more than TOLERANCE over the
baseline; every other shared case is reported informationally (CI runners
are too noisy to gate sub-millisecond cases hard).

The baseline may also carry "ratio_gates": a list of
{"slow": <case>, "fast": <case>, "min_ratio": <x>, "max_ratio": <y>}
entries (at least one of min_ratio/max_ratio required) asserting bounds
on the *measured* slow/fast mean ratio — machine-independent structural
guarantees which absolute nanosecond baselines cannot express.
min_ratio floors a speedup (e.g. ISSUE 4's "warm-start repair >= 5x
faster than a cold replan", ISSUE 6's "flat-arena planner >= 5x faster
than the retained reference", ISSUE 7's "dirty-slot revision repair
>= 5x faster than the full warm portfolio at <= 10% dirty, >= 20x on
an empty-diff re-issue"); max_ratio caps a scaling factor (ISSUE 6's
"10x the jobs costs <= 15x the time").

Refresh the baseline from a quiet machine by copying the measured
mean_ns values from BENCH_scheduler.json into BENCH_baseline.json.
"""

import json
import sys

TOLERANCE = 1.25  # >25% regression fails


def load(path):
    with open(path) as f:
        return json.load(f)


def main(baseline_path, measured_path):
    baseline = load(baseline_path)
    measured = load(measured_path)
    base = {r["name"]: r["mean_ns"] for r in baseline["results"]}
    meas = {r["name"]: r["mean_ns"] for r in measured["results"]}
    gated = set(baseline.get("gated", []))

    failures = []
    # A gated name with no baseline entry would silently disable the gate
    # (e.g. a bench case was renamed but only 'results' was updated).
    for name in sorted(gated - set(base)):
        failures.append(f"gated case {name!r} has no baseline entry — gate misconfigured")
    print(f"{'case':<48} {'baseline':>12} {'measured':>12} {'ratio':>7}")
    for name, base_ns in base.items():
        if name not in meas:
            if name in gated:
                failures.append(f"gated case {name!r} missing from bench output")
            else:
                print(f"{name:<48} {base_ns:>12.0f} {'missing':>12} {'-':>7}")
            continue
        ratio = meas[name] / base_ns if base_ns > 0 else float("inf")
        marker = " <-- GATED" if name in gated else ""
        print(f"{name:<48} {base_ns:>12.0f} {meas[name]:>12.0f} {ratio:>6.2f}x{marker}")
        if name in gated and ratio > TOLERANCE:
            failures.append(
                f"{name}: {meas[name]:.0f} ns vs baseline {base_ns:.0f} ns "
                f"({ratio:.2f}x > {TOLERANCE}x)"
            )

    for name in sorted(set(meas) - set(base)):
        print(f"{name:<48} {'(new case — add to baseline)':>33}")

    for gate in baseline.get("ratio_gates", []):
        slow, fast = gate["slow"], gate["fast"]
        if "min_ratio" not in gate and "max_ratio" not in gate:
            failures.append(
                f"ratio gate {slow!r} / {fast!r}: neither min_ratio nor "
                f"max_ratio set — gate misconfigured"
            )
            continue
        if slow not in meas or fast not in meas:
            failures.append(
                f"ratio gate {slow!r} / {fast!r}: case(s) missing from bench output"
            )
            continue
        ratio = meas[slow] / meas[fast] if meas[fast] > 0 else float("inf")
        bounds = []
        ok = True
        if "min_ratio" in gate:
            need = float(gate["min_ratio"])
            bounds.append(f">= {need:.1f}x")
            if ratio < need:
                ok = False
                failures.append(
                    f"ratio gate: {slow} is only {ratio:.2f}x slower than {fast} "
                    f"(need >= {need}x)"
                )
        if "max_ratio" in gate:
            cap = float(gate["max_ratio"])
            bounds.append(f"<= {cap:.1f}x")
            if ratio > cap:
                ok = False
                failures.append(
                    f"ratio gate: {slow} is {ratio:.2f}x slower than {fast} "
                    f"(need <= {cap}x)"
                )
        print(f"ratio {slow!r} / {fast!r} = {ratio:.1f}x (need {', '.join(bounds)})"
              f"{' OK' if ok else ' FAIL'}")

    if failures:
        print("\nFAIL: fleet-scale benchmark regression(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nOK: no gated regressions.")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        print(f"usage: {sys.argv[0]} <baseline.json> <measured.json>", file=sys.stderr)
        sys.exit(2)
    sys.exit(main(sys.argv[1], sys.argv[2]))
