#!/usr/bin/env python3
"""Fold CI-measured artifacts back into the committed ledgers.

The authoring environment has no Rust toolchain, so EXPERIMENTS.md's
measured columns and BENCH_baseline.json's absolute numbers are seeded
from budgets until a measured refresh lands. CI produces the two
artifacts on every run:

  * EXPERIMENTS_measured.txt  (check job: full expt fleet/geo/online/service runs)
  * BENCH_scheduler.json      (bench job: benches/scheduler.rs output)

This script applies them:

  paste_measured.py --experiments EXPERIMENTS_measured.txt
      copies the artifact to the repo root (committed alongside
      EXPERIMENTS.md) and flips the fleet/geo/online/service measured
      columns from "pending CI refresh" to a pointer at the committed
      tables, stamped with the artifact's content hash so staleness is
      detectable.

  paste_measured.py --bench BENCH_scheduler.json
      copies each measured mean_ns over the matching entry in
      BENCH_baseline.json (names not present in the baseline are
      reported, not invented; gates and ratio_gates are left untouched).

CI runs both modes against the artifacts it just produced and uploads
the patched files as the measured-refresh artifacts — committing those
from any toolchain-bearing checkout completes the refresh. Exit status
is nonzero when an artifact is malformed or matches nothing, so a
renamed bench case or experiment cannot silently disable the refresh
path.
"""

import argparse
import hashlib
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[2]
EXPERIMENT_IDS = ("fleet", "geo", "online", "service")
PENDING_MARKER = "pending CI refresh"


def fail(msg):
    print(f"paste_measured: error: {msg}", file=sys.stderr)
    return 1


def apply_experiments(artifact_path):
    artifact = pathlib.Path(artifact_path)
    if not artifact.is_file():
        return fail(f"{artifact} does not exist")
    text = artifact.read_text()
    missing = [eid for eid in EXPERIMENT_IDS if f"# {eid} " not in text]
    if missing:
        return fail(
            f"artifact {artifact} lacks experiment section(s) {missing}; "
            "was the measured-tables step truncated?"
        )
    digest = hashlib.sha256(text.encode()).hexdigest()[:12]
    (ROOT / "EXPERIMENTS_measured.txt").write_text(text)

    exp_md = ROOT / "EXPERIMENTS.md"
    lines = exp_md.read_text().splitlines(keepends=True)
    replaced = 0
    # A cell is refreshable if it still carries the pending marker OR an
    # earlier refresh stamp (idempotent: re-running just updates the
    # artifact hash).
    refreshed_marker = "EXPERIMENTS_measured.txt §"
    for i, line in enumerate(lines):
        row_id = line.split("|")[1].strip() if line.startswith("|") and line.count("|") > 2 else ""
        if row_id not in EXPERIMENT_IDS:
            continue
        cell = next(
            (
                c
                for c in line.split("|")
                if PENDING_MARKER in c or refreshed_marker in c
            ),
            None,
        )
        if cell is None:
            continue
        # Only the measured cell carries a marker, so a plain substring
        # replace cannot touch other columns.
        lines[i] = line.replace(
            cell.strip(),
            f"✓ see EXPERIMENTS_measured.txt §{row_id} (artifact sha256 {digest})",
        )
        replaced += 1
    if replaced == 0:
        return fail(
            f"no EXPERIMENTS.md measured cell carries {PENDING_MARKER!r} or a "
            "refresh stamp — the rows were renamed"
        )
    exp_md.write_text("".join(lines))
    print(f"paste_measured: refreshed {replaced} EXPERIMENTS.md row(s) from {artifact} "
          f"(sha256 {digest})")
    return 0


def apply_bench(measured_path):
    measured_file = pathlib.Path(measured_path)
    if not measured_file.is_file():
        return fail(f"{measured_file} does not exist")
    measured = json.loads(measured_file.read_text())
    meas = {r["name"]: r["mean_ns"] for r in measured["results"]}
    baseline_path = ROOT / "BENCH_baseline.json"
    baseline_text = baseline_path.read_text()
    baseline = json.loads(baseline_text)

    updated = 0
    unmatched = []
    for row in baseline["results"]:
        if row["name"] in meas:
            row["mean_ns"] = int(round(meas[row["name"]]))
            updated += 1
        else:
            unmatched.append(row["name"])
    if updated == 0:
        return fail("no baseline entry matches any measured case — bench renamed wholesale?")
    for name in unmatched:
        print(f"paste_measured: warning: baseline case {name!r} missing from measured run")
    for name in sorted(set(meas) - {r["name"] for r in baseline["results"]}):
        print(f"paste_measured: note: new measured case {name!r} not in baseline")
    stamp = ("Refreshed from a CI-measured BENCH_scheduler.json run via "
             ".github/scripts/paste_measured.py. ")
    if not baseline["note"].startswith(stamp):
        baseline["note"] = stamp + baseline["note"].replace(
            "Absolute values are still seeded",
            "Absolute values were originally seeded",
            1,
        )
    baseline_path.write_text(json.dumps(baseline, indent=2) + "\n")
    print(f"paste_measured: refreshed {updated}/{len(baseline['results'])} baseline mean_ns "
          f"values from {measured_file}")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--experiments", help="path to EXPERIMENTS_measured.txt artifact")
    ap.add_argument("--bench", help="path to BENCH_scheduler.json artifact")
    args = ap.parse_args()
    if not args.experiments and not args.bench:
        ap.error("pass --experiments and/or --bench")
    rc = 0
    if args.experiments:
        rc |= apply_experiments(args.experiments)
    if args.bench:
        rc |= apply_bench(args.bench)
    return rc


if __name__ == "__main__":
    sys.exit(main())
